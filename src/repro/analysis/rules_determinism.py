"""R5 — determinism lint over ``core/``, ``runtime/``, and ``obs/``.

The tick-for-tick equivalence suite (and every pinned scenario metric)
assumes ``core.sim`` and ``core.sim_reference`` are pure functions of
``(stream, config, seed)``.  Three classes of construct silently break
that:

- **wall-clock reads** — ``time.time()``/``monotonic()``/
  ``perf_counter()`` (and ``datetime.now``) leak host timing into
  results;
- **ambient RNG** — the stdlib ``random`` module and numpy's legacy
  global-state API (``np.random.normal`` etc.) draw from hidden, shared
  state; even ``np.random.default_rng()`` *without a seed* is
  nondeterministic.  All randomness must flow through a
  ``default_rng(seed)`` generator handed down explicitly;
- **set-order iteration** — ``for x in {…}`` / ``in set(...)`` iterates
  in hash order, which varies across runs with ``PYTHONHASHSEED``; sets
  must be sorted before iteration (dicts are insertion-ordered and
  fine).

Scope, per tree:

- ``src/repro/core/`` — the packers, profiler, predictor, IRM, both
  simulators, and the Spark baseline all sit on the equivalence-pinned
  path.  **No exemptions**: results must be a pure function of
  ``(stream, config, seed)``.
- ``src/repro/runtime/`` and ``src/repro/obs/`` — decision logic here
  must stay replayable from recorded event logs, so the same three
  classes of construct are linted, with one carve-out: *measurement*
  sites may read the wall clock.  A wall-clock call is exempt when it
  sits inside a function annotated ``@worker_side`` or ``@loop_only``
  (declared timing/measurement affinity) or inside an ``async def``
  (driver plumbing, not decision logic), or anywhere in
  ``runtime/clock.py`` — the one sanctioned wall-clock wrapper
  (``ScaledClock``).  RNG and set-iteration checks get **no**
  exemption anywhere.
"""

from __future__ import annotations

import ast
from typing import List, Optional

from .model import Finding, ModuleIndex, RepoIndex

__all__ = ["check_determinism"]

CORE_PREFIX = "src/repro/core/"
#: trees where wall-clock reads are linted but annotated measurement
#: sites (@worker_side / @loop_only / async def) are exempt
REPLAY_PREFIXES = ("src/repro/runtime/", "src/repro/obs/")
#: the sanctioned wall-clock wrapper — ScaledClock must read the host
#: clock; everything else goes through it
WALL_CLOCK_ALLOWED_MODULES = {"src/repro/runtime/clock.py"}

_WALL_CLOCK = {
    "time.time",
    "time.time_ns",
    "time.monotonic",
    "time.monotonic_ns",
    "time.perf_counter",
    "time.perf_counter_ns",
    "datetime.now",
    "datetime.utcnow",
    "datetime.today",
    "datetime.datetime.now",
    "datetime.datetime.utcnow",
}

#: The only members of ``np.random`` that are deterministic-by-design
#: (explicit generator construction / seeding machinery).
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "PCG64", "Philox"}


def _dotted(node: ast.expr) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_set_expr(node: ast.expr) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("set", "frozenset")
    return False


def _wall_clock_exempt(mod: ModuleIndex, line: int) -> bool:
    """True when ``line`` sits inside a declared measurement site: a
    ``@worker_side`` / ``@loop_only`` function or an ``async def``."""
    for fn in mod.functions:
        end = getattr(fn.node, "end_lineno", fn.node.lineno)
        if fn.node.lineno <= line <= end and (
            fn.worker_side or fn.loop_only or fn.is_async
        ):
            return True
    return False


def check_determinism(index: RepoIndex, root) -> List[Finding]:
    findings: List[Finding] = []
    for mod in index.modules.values():
        in_core = mod.path.startswith(CORE_PREFIX)
        in_replay = mod.path.startswith(REPLAY_PREFIXES)
        if not in_core and not in_replay:
            continue
        # does this module import the stdlib random module (and under
        # what name)?  numpy-as-np is assumed by repo convention.
        random_aliases = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == "random":
                        random_aliases.add(alias.asname or "random")
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                dotted = _dotted(node.func)
                if dotted in _WALL_CLOCK:
                    if in_replay:
                        if (
                            mod.path not in WALL_CLOCK_ALLOWED_MODULES
                            and not _wall_clock_exempt(mod, node.lineno)
                        ):
                            findings.append(
                                Finding(
                                    rule="R5",
                                    path=mod.path,
                                    line=node.lineno,
                                    symbol="",
                                    message=(
                                        f"wall-clock read {dotted}() in "
                                        f"decision logic; route it through "
                                        f"ScaledClock, or annotate the "
                                        f"enclosing function @worker_side/"
                                        f"@loop_only if this is a "
                                        f"measurement site"
                                    ),
                                )
                            )
                    else:
                        findings.append(
                            Finding(
                                rule="R5",
                                path=mod.path,
                                line=node.lineno,
                                symbol="",
                                message=(
                                    f"wall-clock read {dotted}() on the sim path; "
                                    f"core/ results must be a pure function of "
                                    f"(stream, config, seed)"
                                ),
                            )
                        )
                elif dotted is not None:
                    head, _, rest = dotted.partition(".")
                    if head in random_aliases:
                        findings.append(
                            Finding(
                                rule="R5",
                                path=mod.path,
                                line=node.lineno,
                                symbol="",
                                message=(
                                    f"stdlib global RNG call {dotted}(); use an "
                                    f"explicit np.random.default_rng(seed) "
                                    f"generator threaded through the config"
                                ),
                            )
                        )
                    elif (
                        head in ("np", "numpy")
                        and rest.startswith("random.")
                        and rest.split(".")[1] not in _NP_RANDOM_OK
                    ):
                        findings.append(
                            Finding(
                                rule="R5",
                                path=mod.path,
                                line=node.lineno,
                                symbol="",
                                message=(
                                    f"numpy legacy global-state RNG {dotted}(); "
                                    f"draw from a seeded default_rng generator "
                                    f"instead"
                                ),
                            )
                        )
                    if dotted.endswith("default_rng") and not node.args:
                        findings.append(
                            Finding(
                                rule="R5",
                                path=mod.path,
                                line=node.lineno,
                                symbol="",
                                message=(
                                    "unseeded default_rng() on the sim path — "
                                    "pass the config's seed explicitly"
                                ),
                            )
                        )
            elif isinstance(node, ast.For) and _is_set_expr(node.iter):
                findings.append(
                    Finding(
                        rule="R5",
                        path=mod.path,
                        line=node.lineno,
                        symbol="",
                        message=(
                            "iteration over a set is hash-order-dependent "
                            "(varies with PYTHONHASHSEED); sort it or use an "
                            "insertion-ordered dict"
                        ),
                    )
                )
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp, ast.DictComp)):
                for comp in node.generators:
                    if _is_set_expr(comp.iter):
                        findings.append(
                            Finding(
                                rule="R5",
                                path=mod.path,
                                line=node.lineno,
                                symbol="",
                                message=(
                                    "comprehension over a set is hash-order-"
                                    "dependent (varies with PYTHONHASHSEED); "
                                    "sort it first"
                                ),
                            )
                        )
    return findings
