"""R3 (frozen-reference guard) and R4 (wire-contract drift).

R3 — ``core/sim_reference.py`` is the pre-refactor simulator the
tick-for-tick equivalence suite pins the fast sim against.  Its whole
value is that it never changes: the rule pins its content by SHA-256
(``frozen_manifest.json``) and restricts who may import it to the
equivalence/parity suites and the throughput benchmark that measures the
speedup against it.  A drive-by edit or a convenience import elsewhere is
a finding.

R4 — every class the multiproc transport pickles across the process
boundary has its field set registered in ``wire_manifest.json``.  Adding
a field silently widens the wire format: old pickles stop carrying it,
mixed-version master/worker pairs disagree, and the contract suite
(``tests/test_wire_contract.py``) no longer proves round-trip fidelity.
The rule compares each class's AST field set (dataclass annotations or
``__slots__``) against the manifest *and* requires every registered
field to be exercised by the contract test.
"""

from __future__ import annotations

import ast
import hashlib
from pathlib import Path
from typing import List, Optional, Set

from .model import Finding, RepoIndex, load_packaged_json

__all__ = ["check_frozen_reference", "check_wire_contract"]


def _sha256(path: Path) -> str:
    return hashlib.sha256(path.read_bytes()).hexdigest()


def _import_hits(tree: ast.Module, module_name: str, symbols: Set[str]) -> List[int]:
    """Lines importing ``module_name`` (by module path) or any of ``symbols``."""
    lines: List[int] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if module_name in alias.name.split("."):
                    lines.append(node.lineno)
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if module_name in mod.split("."):
                lines.append(node.lineno)
                continue
            for alias in node.names:
                if alias.name in symbols:
                    lines.append(node.lineno)
                    break
    return lines


def check_frozen_reference(index: RepoIndex, root) -> List[Finding]:
    """R3: content-hash pin + import allowlist for frozen files."""
    findings: List[Finding] = []
    manifest = load_packaged_json("frozen_manifest.json")
    for entry in manifest["frozen"]:
        rel = entry["path"]
        target = Path(root) / rel
        mod_name = Path(rel).stem
        if target.is_file():
            actual = _sha256(target)
            if actual != entry["sha256"]:
                findings.append(
                    Finding(
                        rule="R3",
                        path=rel,
                        line=1,
                        symbol="",
                        message=(
                            f"frozen file modified (sha256 {actual[:12]}… != "
                            f"pinned {entry['sha256'][:12]}…): {entry['reason']} "
                            f"If the change is truly intended, re-pin the hash "
                            f"in src/repro/analysis/frozen_manifest.json in the "
                            f"same commit and say why in the commit message."
                        ),
                    )
                )
        else:
            findings.append(
                Finding(
                    rule="R3",
                    path=rel,
                    line=1,
                    symbol="",
                    message="frozen file is missing from the tree",
                )
            )
        allow = set(entry["import_allowlist"]) | {rel}
        symbols = set(entry.get("symbols", ()))
        for mod in index.modules.values():
            if mod.path in allow:
                continue
            for line in _import_hits(mod.tree, mod_name, symbols):
                findings.append(
                    Finding(
                        rule="R3",
                        path=mod.path,
                        line=line,
                        symbol="",
                        message=(
                            f"import of frozen reference {mod_name} outside "
                            f"the equivalence/parity allowlist; the reference "
                            f"sim exists only to pin the fast sim — import "
                            f"repro.core.sim instead"
                        ),
                    )
                )
    return findings


def _dataclass_fields(cls: ast.ClassDef) -> List[str]:
    out: List[str] = []
    for node in cls.body:
        if isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
            if not node.target.id.startswith("_"):
                out.append(node.target.id)
    return out


def _slots_fields(cls: ast.ClassDef) -> Optional[List[str]]:
    for node in cls.body:
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == "__slots__":
                    if isinstance(node.value, (ast.Tuple, ast.List)):
                        return [
                            e.value
                            for e in node.value.elts
                            if isinstance(e, ast.Constant) and isinstance(e.value, str)
                        ]
    return None


def _test_tokens(tree: ast.Module) -> Set[str]:
    """Every attribute name, keyword-arg name, and string constant the
    contract test touches — a field counts as exercised if it appears as
    any of the three."""
    tokens: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute):
            tokens.add(node.attr)
        elif isinstance(node, ast.keyword) and node.arg:
            tokens.add(node.arg)
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            tokens.add(node.value)
    return tokens


def check_wire_contract(index: RepoIndex, root) -> List[Finding]:
    """R4: pickled-class field sets match the wire manifest + are tested."""
    findings: List[Finding] = []
    manifest = load_packaged_json("wire_manifest.json")
    test_path = manifest["contract_test"]
    test_mod = index.module(test_path)
    tokens = _test_tokens(test_mod.tree) if test_mod is not None else None
    if test_mod is None:
        findings.append(
            Finding(
                rule="R4",
                path=test_path,
                line=1,
                symbol="",
                message="wire-contract test file is missing",
            )
        )
    for cls_name, spec in manifest["classes"].items():
        mod = index.module(spec["path"])
        if mod is None:
            findings.append(
                Finding(
                    rule="R4",
                    path=spec["path"],
                    line=1,
                    symbol=cls_name,
                    message="wire-manifest class's module is missing",
                )
            )
            continue
        cls = mod.classes().get(cls_name)
        if cls is None:
            findings.append(
                Finding(
                    rule="R4",
                    path=spec["path"],
                    line=1,
                    symbol=cls_name,
                    message="wire-manifest class not found in its module",
                )
            )
            continue
        if spec["kind"] == "slots":
            fields = _slots_fields(cls) or []
        else:
            fields = _dataclass_fields(cls)
        declared = set(spec["fields"])
        actual = set(fields)
        for extra in sorted(actual - declared):
            findings.append(
                Finding(
                    rule="R4",
                    path=spec["path"],
                    line=cls.lineno,
                    symbol=cls_name,
                    message=(
                        f"wire-contract drift: field {extra!r} of {cls_name} "
                        f"crosses the transport but is not registered in "
                        f"wire_manifest.json — register it AND extend "
                        f"{test_path} to round-trip it"
                    ),
                )
            )
        for missing in sorted(declared - actual):
            findings.append(
                Finding(
                    rule="R4",
                    path=spec["path"],
                    line=cls.lineno,
                    symbol=cls_name,
                    message=(
                        f"stale wire manifest: {cls_name}.{missing} is "
                        f"registered but no longer exists on the class"
                    ),
                )
            )
        if tokens is not None:
            for field in sorted(declared & actual):
                if field not in tokens:
                    findings.append(
                        Finding(
                            rule="R4",
                            path=test_path,
                            line=1,
                            symbol=cls_name,
                            message=(
                                f"wire field {cls_name}.{field} is never "
                                f"exercised by the contract test — a pickle "
                                f"regression on it would go unnoticed"
                            ),
                        )
                    )
    return findings
