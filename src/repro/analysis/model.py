"""Shared model for the invariant checker: findings + an AST index.

The checker is deliberately dependency-free (stdlib ``ast`` only) so the
CI gate needs nothing but a Python interpreter: it never imports the code
under analysis, it *parses* it.  ``RepoIndex`` walks the three analyzed
trees (``src/``, ``tests/``, ``benchmarks/``), parses every ``.py`` file
once, and records each function with:

- its dotted qualname (``module:Class.method`` / nested chains),
- whether it is an ``async def``,
- its affinity annotations (``@loop_only`` / ``@worker_side`` from
  ``repro.runtime.annotations``), with nested functions inheriting the
  enclosing function's annotations (a thread target defined inside a
  ``@worker_side`` entry point is worker-side too),
- the raw AST node, for the rules to scan.

Files that fail to parse become findings themselves (rule ``parse``)
rather than silent gaps in coverage.
"""

from __future__ import annotations

import ast
import dataclasses
import json
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Finding",
    "FunctionInfo",
    "ModuleIndex",
    "RepoIndex",
    "ANALYZED_TREES",
    "decorator_name",
    "load_packaged_json",
]

#: Trees the checker walks, relative to the repo root.
ANALYZED_TREES = ("src", "tests", "benchmarks")

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache"}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One invariant violation, stable enough to key a suppression on."""

    rule: str          # "R1".."R5" or "parse"
    path: str          # repo-root-relative, forward slashes
    line: int
    symbol: str        # qualname of the enclosing function/class, or ""
    message: str

    def key(self) -> str:
        """Baseline key: everything except the line number, so a pure
        line-shift (edits above the finding) cannot invalidate a
        suppression while an actual content change does."""
        return f"{self.rule}:{self.path}:{self.symbol}:{self.message}"

    def to_json(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def decorator_name(node: ast.expr) -> Optional[str]:
    """The bare name of a decorator expression (``@x``, ``@m.x``, ``@x(...)``)."""
    if isinstance(node, ast.Call):
        node = node.func
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _blocking_reason(node: ast.expr) -> Tuple[bool, Optional[str]]:
    """(has_blocking_kwarg, reason) for a ``@loop_only(blocking=...)`` call."""
    if not isinstance(node, ast.Call):
        return False, None
    for kw in node.keywords:
        if kw.arg == "blocking":
            if isinstance(kw.value, ast.Constant) and isinstance(kw.value.value, str):
                return True, kw.value.value
            return True, None
    return False, None


@dataclasses.dataclass
class FunctionInfo:
    """One ``def``/``async def`` with its affinity annotations resolved."""

    qualname: str                  # e.g. "MultiprocTransport._on_pull"
    name: str
    node: ast.AST                  # FunctionDef | AsyncFunctionDef
    path: str                      # repo-relative file path
    is_async: bool
    loop_only: bool = False
    worker_side: bool = False
    blocking_reason: Optional[str] = None   # set iff @loop_only(blocking=...)
    has_blocking_kwarg: bool = False
    parent: Optional["FunctionInfo"] = None  # enclosing function, if nested
    owner_class: Optional[str] = None        # immediately enclosing class

    @property
    def line(self) -> int:
        return self.node.lineno

    def allows_blocking(self) -> bool:
        return self.worker_side or (
            self.loop_only and bool(self.blocking_reason)
        )


class ModuleIndex:
    """Parsed view of one file: its tree plus every function in it."""

    def __init__(self, path: str, tree: ast.Module):
        self.path = path
        self.tree = tree
        self.functions: List[FunctionInfo] = []
        self._collect(tree.body, qual_prefix="", parent=None, owner_class=None)

    def _collect(
        self,
        body: Iterable[ast.stmt],
        qual_prefix: str,
        parent: Optional[FunctionInfo],
        owner_class: Optional[str],
    ) -> None:
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                info = FunctionInfo(
                    qualname=qual_prefix + node.name,
                    name=node.name,
                    node=node,
                    path=self.path,
                    is_async=isinstance(node, ast.AsyncFunctionDef),
                    parent=parent,
                    owner_class=owner_class,
                )
                for dec in node.decorator_list:
                    dname = decorator_name(dec)
                    if dname == "loop_only":
                        info.loop_only = True
                        has_kw, reason = _blocking_reason(dec)
                        info.has_blocking_kwarg = has_kw
                        info.blocking_reason = reason
                    elif dname == "worker_side":
                        info.worker_side = True
                # nested defs inherit the enclosing affinity (a thread
                # target inside a @worker_side entry point is worker-side)
                if parent is not None:
                    info.loop_only = info.loop_only or parent.loop_only
                    info.worker_side = info.worker_side or parent.worker_side
                    if info.blocking_reason is None:
                        info.blocking_reason = parent.blocking_reason
                self.functions.append(info)
                self._collect(
                    node.body,
                    qual_prefix=info.qualname + ".",
                    parent=info,
                    owner_class=owner_class,
                )
            elif isinstance(node, ast.ClassDef):
                self._collect(
                    node.body,
                    qual_prefix=qual_prefix + node.name + ".",
                    parent=parent,
                    owner_class=node.name,
                )
            elif isinstance(node, (ast.If, ast.Try, ast.With)):
                # functions defined under guards (TYPE_CHECKING, try/except
                # import fallbacks) still count
                for child_body in _stmt_bodies(node):
                    self._collect(child_body, qual_prefix, parent, owner_class)

    def classes(self) -> Dict[str, ast.ClassDef]:
        return {
            n.name: n
            for n in ast.walk(self.tree)
            if isinstance(n, ast.ClassDef)
        }


def _stmt_bodies(node: ast.stmt) -> List[List[ast.stmt]]:
    out: List[List[ast.stmt]] = []
    for field in ("body", "orelse", "finalbody"):
        got = getattr(node, field, None)
        if got:
            out.append(got)
    for handler in getattr(node, "handlers", []) or []:
        out.append(handler.body)
    return out


class RepoIndex:
    """All parsed modules of the analyzed trees, plus name-based lookup."""

    def __init__(self, root: Path, trees: Iterable[str] = ANALYZED_TREES):
        self.root = Path(root)
        self.modules: Dict[str, ModuleIndex] = {}
        self.parse_findings: List[Finding] = []
        for tree_name in trees:
            base = self.root / tree_name
            if not base.is_dir():
                continue
            for py in sorted(base.rglob("*.py")):
                if _SKIP_DIRS.intersection(py.relative_to(self.root).parts):
                    continue
                rel = py.relative_to(self.root).as_posix()
                try:
                    tree = ast.parse(py.read_text(encoding="utf-8"))
                except SyntaxError as exc:
                    self.parse_findings.append(
                        Finding(
                            rule="parse",
                            path=rel,
                            line=exc.lineno or 0,
                            symbol="",
                            message=f"file does not parse: {exc.msg}",
                        )
                    )
                    continue
                self.modules[rel] = ModuleIndex(rel, tree)
        # name -> every function with that name, across the src/ tree only
        # (call resolution never follows edges into tests/benchmarks).
        # Functions nested inside another function are excluded: they are
        # local names, unreachable by attribute/name from any other scope,
        # so letting them shadow a module-level or method name (e.g. a
        # worker-side local `now()` vs `ScaledClock.now`) only fabricates
        # edges that cannot exist.
        self._by_name: Dict[str, List[FunctionInfo]] = {}
        for mod in self.modules.values():
            if not mod.path.startswith("src/"):
                continue
            for fn in mod.functions:
                if fn.parent is not None:
                    continue
                self._by_name.setdefault(fn.name, []).append(fn)

    def src_functions(self, prefix: str = "src/") -> List[FunctionInfo]:
        out: List[FunctionInfo] = []
        for mod in self.modules.values():
            if mod.path.startswith(prefix):
                out.extend(mod.functions)
        return out

    def resolve_call(self, name: str) -> List[FunctionInfo]:
        """Every src/ function a call to ``name`` might reach.

        Name-based over-approximation: ``pool.kill_worker(...)`` resolves
        to *every* ``kill_worker`` in ``src/`` — exactly what a
        multi-implementation interface (``Transport``) needs, at the cost
        of occasionally traversing an unrelated same-named function.
        """
        return self._by_name.get(name, [])

    def module(self, rel_path: str) -> Optional[ModuleIndex]:
        return self.modules.get(rel_path)


def load_packaged_json(filename: str) -> dict:
    """Load a JSON data file shipped inside ``repro.analysis``."""
    here = Path(__file__).resolve().parent
    with open(here / filename, encoding="utf-8") as fh:
        return json.load(fh)
