"""R1 (blocking-in-async) and R2 (single-consumer / thread affinity).

Both rules protect the live runtime's lock-free design (see
docs/ARCHITECTURE.md "Checked invariants"):

R1 — the event loop must never block.  Every function reachable on the
loop thread from an ``async def`` in ``src/repro/runtime/`` is scanned
for blocking primitives (``time.sleep``, blocking ``Queue.get/put``,
``Thread/Process.join``, file ``open``, ``subprocess``, the payloads'
``run_sync``).  ``@worker_side`` bodies are exempt — they run on worker
threads/processes where blocking is the point — but an edge from
loop-reachable code *into* a ``@worker_side`` function is itself a
finding.  A deliberate blocking section on the loop thread (the kill
path's synchronous data-channel tail-drain, teardown joins) must carry
``@loop_only(blocking="reason")``.

R2 — state affinity.  The multiproc data channel is single-consumer by
construction: only ``@loop_only`` code may read ``data_q``.  Master-side
mirrors (``LivePE.state/.msg/.idle_since``, a worker's ``pes`` list) and
the ``Master``'s queue-mutating methods may only be touched from
``@loop_only`` functions or ``async def``s (which run on the loop by
construction) — and never from ``@worker_side`` code.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Tuple

from .callgraph import body_calls, reachable_from_async
from .model import Finding, FunctionInfo, RepoIndex

__all__ = ["check_blocking_in_async", "check_affinity"]

RUNTIME_PREFIX = "src/repro/runtime/"
#: Call edges are resolved only into the control plane: the runtime
#: package itself plus the core algorithms the driver invokes per tick.
CONTROL_PLANE_PREFIXES = (RUNTIME_PREFIX, "src/repro/core/")

#: Mirror attributes whose assignment is loop-thread-only (R2).
MIRROR_ATTRS = {"state", "msg", "idle_since", "pes"}

#: Master methods that mutate the backlog queues (R2).
MASTER_MUTATORS = {"pull", "push_back", "push_front", "requeue", "complete"}

_QUEUE_GET = {"get"}
_JOIN_RECEIVERS = ("proc", "process", "thread")


def _receiver_tail(func: ast.expr) -> Optional[str]:
    """Syntactic name of a method call's receiver: ``h.cmd_q.put`` → ``cmd_q``."""
    if not isinstance(func, ast.Attribute):
        return None
    value = func.value
    if isinstance(value, ast.Attribute):
        return value.attr
    if isinstance(value, ast.Name):
        return value.id
    return None


def _receiver_chain(func: ast.expr) -> List[str]:
    """All names along a call's receiver chain: ``self.pool.master.requeue``
    → ``["self", "pool", "master"]``."""
    names: List[str] = []
    node = func.value if isinstance(func, ast.Attribute) else None
    while node is not None:
        if isinstance(node, ast.Attribute):
            names.append(node.attr)
            node = node.value
        elif isinstance(node, ast.Name):
            names.append(node.id)
            node = None
        else:
            node = getattr(node, "value", None) if isinstance(node, ast.Subscript) else None
    return names


def _is_queue_like(name: Optional[str]) -> bool:
    if name is None:
        return False
    return name == "q" or name.endswith("_q") or name == "queue" or name.endswith("_queue")


def _dotted(node: ast.expr) -> Optional[str]:
    """``time.sleep`` → "time.sleep"; ``np.random.normal`` → "np.random.normal"."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _blocking_call(call: ast.Call) -> Optional[str]:
    """A human-readable label if this call is a blocking primitive."""
    func = call.func
    dotted = _dotted(func)
    if dotted in ("time.sleep",):
        return dotted
    if dotted is not None and dotted.split(".", 1)[0] in ("subprocess",):
        return dotted
    if dotted in ("os.system", "os.wait", "os.waitpid"):
        return dotted
    if isinstance(func, ast.Name) and func.id == "open":
        return "open()"
    if isinstance(func, ast.Attribute):
        attr = func.attr
        tail = _receiver_tail(func)
        if attr == "run_sync":
            return f"{tail or '<expr>'}.run_sync() (worker-side blocking payload)"
        if attr in _QUEUE_GET | {"put"} and _is_queue_like(tail):
            return f"{tail}.{attr}() (blocking queue op; use {attr}_nowait)"
        if attr == "join" and tail is not None and (
            tail in _JOIN_RECEIVERS
            or any(tail.endswith(s) for s in _JOIN_RECEIVERS)
        ):
            return f"{tail}.join()"
    return None


def _scan_blocking(fn: FunctionInfo) -> Iterator[Tuple[int, str]]:
    for call in body_calls(fn):
        label = _blocking_call(call)
        if label is not None:
            yield call.lineno, label


def check_blocking_in_async(index: RepoIndex, root) -> List[Finding]:
    """R1: no blocking primitive reachable from async bodies in runtime/."""
    findings: List[Finding] = []
    reached, boundary = reachable_from_async(
        index, RUNTIME_PREFIX, resolve_prefixes=CONTROL_PLANE_PREFIXES
    )
    for caller, callee, line in boundary:
        findings.append(
            Finding(
                rule="R1",
                path=caller.path,
                line=line,
                symbol=caller.qualname,
                message=(
                    f"loop-reachable code calls @worker_side function "
                    f"{callee.qualname} ({callee.path}); worker-side code "
                    f"must be dispatched via a thread/process/executor, "
                    f"never invoked on the event loop"
                ),
            )
        )
    for fn in reached.values():
        if fn.allows_blocking():
            continue
        for line, label in _scan_blocking(fn):
            findings.append(
                Finding(
                    rule="R1",
                    path=fn.path,
                    line=line,
                    symbol=fn.qualname,
                    message=(
                        f"blocking call {label} reachable from async code; "
                        f"move it worker-side (@worker_side) or annotate a "
                        f"deliberate stall with @loop_only(blocking=...)"
                    ),
                )
            )
    return findings


def _assigned_mirror_attrs(fn: FunctionInfo) -> Iterator[Tuple[int, str, str]]:
    """(line, receiver, attr) for mirror-attribute assignments in ``fn``."""
    stack: List[ast.AST] = list(ast.iter_child_nodes(fn.node))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        targets: List[ast.expr] = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        for tgt in targets:
            if isinstance(tgt, ast.Tuple):
                sub = list(tgt.elts)
            else:
                sub = [tgt]
            for t in sub:
                if (
                    isinstance(t, ast.Attribute)
                    and t.attr in MIRROR_ATTRS
                    and not (isinstance(t.value, ast.Name) and t.value.id == "self")
                ):
                    recv = _dotted(t.value) or "<expr>"
                    yield node.lineno, recv, t.attr
        stack.extend(ast.iter_child_nodes(node))


def check_affinity(index: RepoIndex, root) -> List[Finding]:
    """R2: data-channel single-consumer + mirror/master mutation affinity."""
    findings: List[Finding] = []
    for fn in index.src_functions(RUNTIME_PREFIX):
        on_loop = fn.loop_only or (fn.is_async and not fn.worker_side)
        # --- annotation vocabulary consistency --------------------------------
        if fn.loop_only and fn.worker_side:
            findings.append(
                Finding(
                    rule="R2",
                    path=fn.path,
                    line=fn.line,
                    symbol=fn.qualname,
                    message="function annotated both @loop_only and @worker_side",
                )
            )
        if fn.has_blocking_kwarg and not fn.blocking_reason:
            findings.append(
                Finding(
                    rule="R2",
                    path=fn.path,
                    line=fn.line,
                    symbol=fn.qualname,
                    message=(
                        "@loop_only(blocking=...) requires a non-empty literal "
                        "reason string explaining why stalling the loop is safe"
                    ),
                )
            )
        # --- mirror mutations -------------------------------------------------
        for line, recv, attr in _assigned_mirror_attrs(fn):
            if fn.worker_side:
                findings.append(
                    Finding(
                        rule="R2",
                        path=fn.path,
                        line=line,
                        symbol=fn.qualname,
                        message=(
                            f"@worker_side code mutates master-side mirror "
                            f"state ({recv}.{attr}); mirrors are loop-thread-"
                            f"only — report through the data channel instead"
                        ),
                    )
                )
            elif not on_loop:
                findings.append(
                    Finding(
                        rule="R2",
                        path=fn.path,
                        line=line,
                        symbol=fn.qualname,
                        message=(
                            f"mirror mutation {recv}.{attr} outside @loop_only: "
                            f"annotate the function (it must only run on the "
                            f"event-loop thread) or move the mutation"
                        ),
                    )
                )
        # --- master queue mutations + data-channel reads ----------------------
        for call in body_calls(fn):
            func = call.func
            if not isinstance(func, ast.Attribute):
                continue
            attr = func.attr
            tail = _receiver_tail(func)
            if attr in MASTER_MUTATORS and "master" in _receiver_chain(func):
                if fn.worker_side:
                    findings.append(
                        Finding(
                            rule="R2",
                            path=fn.path,
                            line=call.lineno,
                            symbol=fn.qualname,
                            message=(
                                f"@worker_side code calls Master.{attr}(); the "
                                f"master's queues are loop-thread-only"
                            ),
                        )
                    )
                elif not on_loop:
                    findings.append(
                        Finding(
                            rule="R2",
                            path=fn.path,
                            line=call.lineno,
                            symbol=fn.qualname,
                            message=(
                                f"Master.{attr}() called outside @loop_only; "
                                f"queue mutations must stay on the event-loop "
                                f"thread (annotate the caller)"
                            ),
                        )
                    )
            if attr in ("get", "get_nowait") and tail == "data_q":
                if not fn.loop_only:
                    findings.append(
                        Finding(
                            rule="R2",
                            path=fn.path,
                            line=call.lineno,
                            symbol=fn.qualname,
                            message=(
                                "data_q read outside a @loop_only function: the "
                                "multiproc data channel is single-consumer — "
                                "only the poller and the kill-path drain (both "
                                "on the loop thread) may consume it"
                            ),
                        )
                    )
    return findings
