"""Continuous-batching serving engine managed by the paper's IRM.

The HarmonicIO mapping, one-to-one:

  stream message   -> inference request (prompt + max_new_tokens)
  PE container     -> an admitted request occupying a decode slot + KV pages
  worker VM (bin)  -> a serving replica with capacity 1.0
                      (vector capacity: decode slots x KV pages)
  worker profiler  -> per-request-class cost profile (moving average of
                      measured slot-seconds and page usage)
  load predictor   -> request-queue length + ROC -> replica scale-up
  container queue  -> admission queue with TTL requeue on failed placement
  bin-packing run  -> First-Fit admission of queued requests onto replicas

Two execution backends share this control plane:
  - ``SimulatedBackend``: discrete-time replica pool (used by benchmarks —
    deterministic, thousands of requests);
  - ``LocalBackend``: actually runs a (small) model's prefill/decode on the
    local device with a paged KV cache (used by the serving example and
    integration tests).
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.load_predictor import LoadPredictor, LoadPredictorConfig
from ..core.profiler import MasterProfiler, ProfilerConfig
from ..core.queues import ContainerQueue
from .kv_cache import PageAllocator, PagedCacheLayout

__all__ = [
    "Request",
    "ReplicaConfig",
    "EngineConfig",
    "ServingEngine",
    "SimulatedBackend",
    "ServingClusterView",
]

_req_counter = itertools.count()


@dataclasses.dataclass
class Request:
    prompt_len: int
    max_new_tokens: int
    arrival: float = 0.0
    req_class: str = "default"
    req_id: int = dataclasses.field(default_factory=lambda: next(_req_counter))
    # filled during execution
    generated: int = 0
    replica: Optional[int] = None
    start_t: float = -1.0
    done_t: float = -1.0

    @property
    def total_tokens(self) -> int:
        return self.prompt_len + self.max_new_tokens


@dataclasses.dataclass(frozen=True)
class ReplicaConfig:
    max_slots: int = 16            # concurrent decode slots
    kv_pages: int = 2048           # page pool size
    page_size: int = 16            # tokens/page
    prefill_tokens_per_s: float = 50_000.0
    decode_tokens_per_s: float = 2_000.0   # per slot-step round
    spinup_delay: float = 10.0     # compile + weight load


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    replica: ReplicaConfig = dataclasses.field(default_factory=ReplicaConfig)
    max_replicas: int = 8
    dt: float = 0.1
    request_ttl: int = 5
    predictor: LoadPredictorConfig = dataclasses.field(
        default_factory=lambda: LoadPredictorConfig(
            queue_low=4, queue_high=32, roc_low=2.0, roc_high=16.0,
            small_increase=1, large_increase=2, cooldown=5.0,
        )
    )
    profiler: ProfilerConfig = dataclasses.field(
        default_factory=lambda: ProfilerConfig(window=64, default_size=0.25)
    )
    # admission packing heuristic over (slots, pages) vector bins
    packing_heuristic: str = "first"


class _SimReplica:
    """Discrete-time model of one serving replica."""

    def __init__(self, idx: int, cfg: ReplicaConfig, t: float, booted: bool = False):
        self.idx = idx
        self.cfg = cfg
        self.ready_t = t if booted else t + cfg.spinup_delay
        self.active: List[Request] = []
        self.prefilling: List[Tuple[Request, float]] = []
        self.allocator = PageAllocator(
            PagedCacheLayout(
                num_pages=cfg.kv_pages,
                page_size=cfg.page_size,
                n_kv_heads=1,
                head_dim=1,
                max_pages_per_seq=cfg.kv_pages,
            )
        )
        self.retired = False

    def ready(self, t: float) -> bool:
        return t >= self.ready_t and not self.retired

    def load_fraction(self) -> Tuple[float, float]:
        """(slot fraction, page fraction) — the vector bin occupancy."""
        slots = (len(self.active) + len(self.prefilling)) / self.cfg.max_slots
        pages = self.allocator.used_pages / self.cfg.kv_pages
        return slots, pages

    def try_admit(self, req: Request, t: float) -> bool:
        if not self.ready(t):
            return False
        if len(self.active) + len(self.prefilling) >= self.cfg.max_slots:
            return False
        pages = self.allocator.allocate(req.req_id, req.prompt_len)
        if pages is None:
            return False
        req.replica = self.idx
        req.start_t = t
        prefill_time = req.prompt_len / self.cfg.prefill_tokens_per_s
        self.prefilling.append((req, t + prefill_time))
        return True

    def step(self, t: float, dt: float) -> List[Request]:
        """Advance one tick; returns completed requests."""
        done: List[Request] = []
        still = []
        for req, ready_at in self.prefilling:
            if t >= ready_at:
                self.active.append(req)
            else:
                still.append((req, ready_at))
        self.prefilling = still
        if not self.active:
            return done
        # decode round: each active slot generates tokens at the shared rate
        per_slot = self.cfg.decode_tokens_per_s * dt / max(1, len(self.active))
        per_slot = max(per_slot, 0.0)
        finished: List[Request] = []
        for req in self.active:
            req.generated += per_slot
            if self.allocator.extend(req.req_id, int(np.ceil(per_slot))) is None:
                finished.append(req)  # pool exhausted -> finish (simplified)
                continue
            if req.generated >= req.max_new_tokens:
                finished.append(req)
        for req in finished:
            req.done_t = t
            self.active.remove(req)
            self.allocator.free(req.req_id)
            done.append(req)
        return done


class SimulatedBackend:
    """Replica pool with discrete-time execution (benchmark backend)."""

    def __init__(self, cfg: EngineConfig):
        self.cfg = cfg
        self.replicas: List[_SimReplica] = [
            _SimReplica(0, cfg.replica, 0.0, booted=True)
        ]

    def scale_to(self, target: int, t: float) -> None:
        target = min(target, self.cfg.max_replicas)
        alive = [r for r in self.replicas if not r.retired]
        while len(alive) < target:
            r = _SimReplica(len(self.replicas), self.cfg.replica, t)
            self.replicas.append(r)
            alive.append(r)
        # retire idle replicas above target (highest index first)
        for r in reversed(alive):
            if len(alive) <= target:
                break
            if not r.active and not r.prefilling and r.idx != 0:
                r.retired = True
                alive.remove(r)

    def step(self, t: float, dt: float) -> List[Request]:
        out: List[Request] = []
        for r in self.replicas:
            if not r.retired:
                out.extend(r.step(t, dt))
        return out


class ServingEngine:
    """IRM-scheduled continuous batching over a replica backend."""

    def __init__(self, cfg: EngineConfig, backend: Optional[SimulatedBackend] = None):
        self.cfg = cfg
        self.backend = backend or SimulatedBackend(cfg)
        self.queue: deque = deque()
        self.admission = ContainerQueue()
        self.profiler = MasterProfiler(cfg.profiler)
        self.predictor = LoadPredictor(cfg.predictor)
        self.completed: List[Request] = []
        self.t = 0.0
        self.metrics: List[Dict[str, float]] = []
        self._target = 1

    # ---- request intake --------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.arrival = self.t
        self.queue.append(req)

    # ---- cost model (profiled item size) ------------------------------------------
    def _size_estimate(self, req: Request) -> Tuple[float, float]:
        """(slot share, page share) — vector item for admission packing."""
        rc = self.cfg.replica
        slot = 1.0 / rc.max_slots
        pages = min(1.0, req.total_tokens / (rc.kv_pages * rc.page_size))
        # profile-corrected: learned mean page usage per class
        learned = self.profiler.estimate(req.req_class)
        if self.profiler.num_observations(req.req_class) > 0:
            pages = learned
        return slot, pages

    # ---- main loop --------------------------------------------------------------
    def step(self) -> None:
        cfg = self.cfg
        t = self.t

        # (1) load prediction on the request queue
        decision = self.predictor.update(t, float(len(self.queue)))
        if decision.num_pes > 0:
            self._target = min(cfg.max_replicas, self._target + decision.num_pes)
        elif not self.queue and all(
            not r.active and not r.prefilling
            for r in self.backend.replicas
            if not r.retired
        ):
            self._target = 1
        self.backend.scale_to(self._target, t)

        # (2) First-Fit admission over (slots, pages) vector bins
        admitted = True
        while self.queue and admitted:
            admitted = False
            req = self.queue[0]
            for r in self.backend.replicas:
                if r.retired:
                    continue
                if r.try_admit(req, t):
                    self.queue.popleft()
                    admitted = True
                    break

        # (3) advance execution
        done = self.backend.step(t, cfg.dt)
        for req in done:
            self.completed.append(req)
            rc = cfg.replica
            self.profiler.observe(
                req.req_class,
                min(1.0, req.total_tokens / (rc.kv_pages * rc.page_size)),
            )

        # (4) metrics
        alive = [r for r in self.backend.replicas if not r.retired]
        slot_loads = [r.load_fraction()[0] for r in alive]
        page_loads = [r.load_fraction()[1] for r in alive]
        self.metrics.append(
            {
                "t": t,
                "queue": len(self.queue),
                "replicas": len(alive),
                "target": self._target,
                "mean_slot_load": float(np.mean(slot_loads)) if slot_loads else 0.0,
                "mean_page_load": float(np.mean(page_loads)) if page_loads else 0.0,
                "completed": len(self.completed),
            }
        )
        self.t = round(t + cfg.dt, 9)

    def run_until_drained(self, t_max: float = 3600.0) -> None:
        while self.t < t_max:
            self.step()
            if (
                not self.queue
                and all(
                    not r.active and not r.prefilling
                    for r in self.backend.replicas
                    if not r.retired
                )
            ):
                break

    # ---- ClusterView adapter ---------------------------------------------------
    def cluster_view(self) -> "ServingClusterView":
        """A ``core.irm.ClusterView`` over this engine (see the class)."""
        return ServingClusterView(self)

    # ---- summary -----------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        if not self.completed:
            return {"completed": 0}
        lat = [r.done_t - r.arrival for r in self.completed]
        return {
            "completed": len(self.completed),
            "makespan": max(r.done_t for r in self.completed),
            "p50_latency": float(np.percentile(lat, 50)),
            "p99_latency": float(np.percentile(lat, 99)),
            "peak_replicas": max(m["replicas"] for m in self.metrics),
        }


class ServingClusterView:
    """``core.irm.ClusterView`` adapter over a ``ServingEngine``.

    The engine drives the IRM components directly in its own ``step`` (the
    admission loop predates the protocol), but exposing the standard view
    closes the protocol gap so backend-generic tooling — the conformance
    suite, ad-hoc ``IRM.step`` experiments — can observe and actuate a
    serving cluster exactly like the sim and live backends:

      worker/bin  -> a live (non-retired) replica; its scheduled load is
                     the (slots, pages) occupancy as a ``Resources`` vector
                     with dims ``("cpu", "pages")`` (decode slots are the
                     compute dimension, so they map onto dim 0)
      PE/item     -> an admitted request
      try_start_pe-> admit the oldest queued request of the placed class
                     onto the target replica
      scale       -> clamp and apply the engine's replica target
    """

    DIMS = ("cpu", "pages")

    def __init__(self, engine: ServingEngine):
        self.engine = engine

    def queue_length(self) -> float:
        return float(len(self.engine.queue))

    def queue_image_mix(self) -> Dict[str, float]:
        if not self.engine.queue:
            return {}
        counts: Dict[str, int] = {}
        for req in self.engine.queue:
            counts[req.req_class] = counts.get(req.req_class, 0) + 1
        n = float(len(self.engine.queue))
        return {cls: c / n for cls, c in counts.items()}

    def worker_scheduled_loads(self) -> List["Resources"]:
        from ..core.resources import Resources

        out = []
        for r in self.engine.backend.replicas:
            if r.retired:
                out.append(Resources(self.DIMS, (0.0, 0.0)))
            else:
                out.append(Resources(self.DIMS, r.load_fraction()))
        return out

    def backlog_resource_demand(self):
        from ..core.resources import Resources

        total = None
        for req in list(self.engine.queue)[:64]:
            slot, pages = self.engine._size_estimate(req)
            v = Resources(self.DIMS, (slot, pages))
            total = v if total is None else total + v
        return total

    def try_start_pe(self, req) -> bool:
        idx = req.target_worker
        replicas = self.engine.backend.replicas
        if idx is None or idx >= len(replicas) or replicas[idx].retired:
            return False
        for queued in self.engine.queue:
            if queued.req_class == req.image:
                if replicas[idx].try_admit(queued, self.engine.t):
                    self.engine.queue.remove(queued)
                    return True
                return False
        return False

    def scale_workers(self, target: int) -> None:
        self.engine._target = max(1, min(target, self.engine.cfg.max_replicas))
        self.engine.backend.scale_to(self.engine._target, self.engine.t)
