"""Serving substrate: paged KV cache, continuous batching, IRM autoscaling."""

from .engine import (
    EngineConfig,
    ReplicaConfig,
    Request,
    ServingEngine,
    SimulatedBackend,
)
from .kv_cache import PageAllocator, PagedCacheLayout

__all__ = [
    "EngineConfig",
    "ReplicaConfig",
    "Request",
    "ServingEngine",
    "SimulatedBackend",
    "PageAllocator",
    "PagedCacheLayout",
]
