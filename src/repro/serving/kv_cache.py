"""Paged KV cache with a First-Fit page allocator.

HBM pages are the serving-side *bins*: the KV cache is a pool of
fixed-size pages; each sequence owns a page list recorded in a page table.
Allocation is First-Fit over the free list (lowest-index free page first),
which keeps live pages dense at the low end of the pool — the exact analogue
of the paper's Fig. 3, where the packing concentrates load on low-index
workers so the high-index tail can be released (here: handed back, or
defragmented away when a replica scales down).

The device arrays are consumed by ``kernels/paged_attention`` (TPU) or its
jnp reference; the allocator itself is host-side bookkeeping, exactly like
the IRM living on the master node.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional

import numpy as np

__all__ = ["PageAllocator", "PagedCacheLayout"]


@dataclasses.dataclass(frozen=True)
class PagedCacheLayout:
    """Static geometry of the paged cache pool."""

    num_pages: int
    page_size: int          # tokens per page
    n_kv_heads: int
    head_dim: int
    max_pages_per_seq: int

    @property
    def tokens_capacity(self) -> int:
        return self.num_pages * self.page_size

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)


class PageAllocator:
    """First-Fit (lowest-index) page allocation with per-sequence tables."""

    def __init__(self, layout: PagedCacheLayout):
        self.layout = layout
        self._free: List[int] = list(range(layout.num_pages))
        heapq.heapify(self._free)
        self._owned: Dict[int, List[int]] = {}   # seq_id -> page list
        self._lengths: Dict[int, int] = {}       # seq_id -> token count
        self.peak_pages_used = 0

    # ---- queries ------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.layout.num_pages - len(self._free)

    def utilization(self) -> float:
        """Token-level utilization of allocated pages (packing efficiency)."""
        if not self._owned:
            return 0.0
        used_tokens = sum(self._lengths.values())
        return used_tokens / (self.used_pages * self.layout.page_size)

    def highest_used_page(self) -> int:
        """Max live page index + 1 (the 'bins in use' watermark, Fig. 10)."""
        top = -1
        for pages in self._owned.values():
            if pages:
                top = max(top, max(pages))
        return top + 1

    def can_fit(self, n_tokens: int) -> bool:
        return self.layout.pages_for(n_tokens) <= len(self._free)

    def seq_pages(self, seq_id: int) -> List[int]:
        return list(self._owned.get(seq_id, ()))

    def seq_len(self, seq_id: int) -> int:
        return self._lengths.get(seq_id, 0)

    # ---- allocation -----------------------------------------------------------
    def allocate(self, seq_id: int, n_tokens: int) -> Optional[List[int]]:
        """Allocate pages for a new sequence; None if it doesn't fit."""
        if seq_id in self._owned:
            raise KeyError(f"sequence {seq_id} already allocated")
        need = self.layout.pages_for(max(1, n_tokens))
        if need > len(self._free) or need > self.layout.max_pages_per_seq:
            return None
        pages = [heapq.heappop(self._free) for _ in range(need)]
        self._owned[seq_id] = pages
        self._lengths[seq_id] = n_tokens
        self.peak_pages_used = max(self.peak_pages_used, self.used_pages)
        return list(pages)

    def extend(self, seq_id: int, n_new_tokens: int = 1) -> Optional[List[int]]:
        """Grow a sequence; returns newly allocated pages (possibly empty)."""
        if seq_id not in self._owned:
            raise KeyError(f"sequence {seq_id} not allocated")
        old_len = self._lengths[seq_id]
        new_len = old_len + n_new_tokens
        have = len(self._owned[seq_id])
        need = self.layout.pages_for(new_len)
        if need > self.layout.max_pages_per_seq:
            return None
        fresh: List[int] = []
        while have + len(fresh) < need:
            if not self._free:
                return None  # pool exhausted: caller must evict/preempt
            fresh.append(heapq.heappop(self._free))
        self._owned[seq_id].extend(fresh)
        self._lengths[seq_id] = new_len
        self.peak_pages_used = max(self.peak_pages_used, self.used_pages)
        return fresh

    def free(self, seq_id: int) -> int:
        """Release a sequence's pages back to the free list."""
        pages = self._owned.pop(seq_id, [])
        self._lengths.pop(seq_id, None)
        for p in pages:
            heapq.heappush(self._free, p)
        return len(pages)

    # ---- page-table export ------------------------------------------------------
    def page_table(self, seq_ids: List[int]) -> np.ndarray:
        """(len(seq_ids), max_pages_per_seq) int32 table; -1 = unused slot."""
        t = np.full((len(seq_ids), self.layout.max_pages_per_seq), -1, np.int32)
        for row, sid in enumerate(seq_ids):
            pages = self._owned.get(sid, [])
            t[row, : len(pages)] = pages
        return t
