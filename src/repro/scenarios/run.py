"""Scenario CLI: ``PYTHONPATH=src python -m repro.scenarios.run``.

One entry point for every registered workload:

  # list the catalogue
  python -m repro.scenarios.run --list

  # the paper's synthetic experiment under First-Fit (same metrics the
  # fig3/4/5 benchmarks record)
  python -m repro.scenarios.run synthetic --policy first-fit

  # sweep the whole Any-Fit family on the microscopy use case
  python -m repro.scenarios.run microscopy --policy all

  # seconds-long deterministic smoke run (CI)
  python -m repro.scenarios.run bursty --smoke

  # the same scenario on the live asyncio master/worker runtime
  python -m repro.scenarios.run microscopy --smoke --backend live --time-scale 0.01

  # workers as OS processes behind pickled command/data queues
  python -m repro.scenarios.run microscopy --smoke --backend multiproc

  # the same stream through the continuous-batching serving backend
  python -m repro.scenarios.run bursty --backend serving --smoke

``--out DIR`` writes the per-tick time series (scheduled/measured CPU per
worker, error, queue length, worker counts — the exact columns the paper's
figure benchmarks dump) as CSV plus a JSON summary per policy.
"""

from __future__ import annotations

import argparse
import csv
import json
import os
import sys
from typing import Dict, List, Optional

from ..runtime.payloads import PAYLOADS
from .engine import (
    POLICIES,
    VECTOR_POLICIES,
    ScenarioResult,
    policies_for,
    run_scenario,
    sweep_policies,
)
from .registry import get_scenario, list_scenarios


def _dump_tick_csv(path: str, result: ScenarioResult) -> None:
    res = result.final
    W = res.scheduled_cpu.shape[1]
    header = (
        ["t"]
        + [f"sched_w{i}" for i in range(W)]
        + [f"meas_w{i}" for i in range(W)]
        + [f"err_w{i}" for i in range(W)]
        + ["queue_len", "active_workers", "target_workers", "ideal_bins",
           "pe_count"]
    )
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        err = res.error
        for i, t in enumerate(res.times):
            w.writerow(
                [float(t)]
                + [float(x) for x in res.scheduled_cpu[i]]
                + [float(x) for x in res.measured_cpu[i]]
                + [float(x) for x in err[i]]
                + [
                    float(res.queue_len[i]),
                    int(res.active_workers[i]),
                    int(res.target_workers[i]),
                    int(res.ideal_bins[i]),
                    int(res.pe_count[i]),
                ]
            )


def _print_summary(result: ScenarioResult) -> None:
    backend = "" if result.backend == "sim" else f" · backend {result.backend!r}"
    print(f"\n=== scenario {result.scenario!r} · policy {result.policy!r}"
          f"{backend} ===")
    for k, v in result.summary.items():
        if isinstance(v, float):
            print(f"  {k}: {v:.4g}")
        else:
            print(f"  {k}: {v}")
    if result.expectations:
        print("  expectations:")
        for name, ok in result.expectations.items():
            print(f"    [{'PASS' if ok else 'FAIL'}] {name}")


def _smoke_note(scn) -> None:
    print(
        f"(smoke run: {scn.smoke_overrides}; expectations are calibrated "
        "for the full-scale scenario and may not all hold at smoke scale)"
    )


def _list(args: argparse.Namespace) -> int:
    print(
        f"{'name':<14} {'runs':>4}  {'dims':<10} {'policies':<8} "
        f"{'backends':<27} {'tags':<24} description"
    )
    print("-" * 120)
    for scn in list_scenarios():
        tags = ",".join(scn.tags)
        dims = getattr(scn.sim_config(), "resource_dims", ("cpu",))
        family = "vector" if len(dims) > 1 else "any-fit"
        backends = ",".join(scn.backends)
        print(
            f"{scn.name:<14} {scn.n_runs:>4}  {'+'.join(dims):<10} "
            f"{family:<8} {backends:<27} {tags:<24} {scn.description}"
        )
        if args.verbose:
            for e in scn.expectations:
                print(f"{'':20}  expects: {e.name} — {e.description}")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.scenarios.run",
        description="Run a registered workload scenario through the IRM.",
    )
    ap.add_argument("scenario", nargs="?", help="scenario name (see --list)")
    ap.add_argument("--list", action="store_true", help="list scenarios")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="with --list: also print expectations")
    ap.add_argument(
        "--policy", default=None,
        help="packing policy, comma-separated for a sweep, or 'all' — the "
        f"scenario's policy family: scalar ({', '.join(POLICIES)}) or, for "
        f"multi-resource scenarios, vector ({', '.join(VECTOR_POLICIES)}); "
        "default: the scenario's configured policy",
    )
    ap.add_argument("--backend",
                    choices=("sim", "live", "multiproc", "serving"),
                    default="sim",
                    help="cluster sim (paper testbed), live asyncio "
                    "master/worker runtime, the same runtime with workers "
                    "as OS processes (multiproc), or serving engine")
    ap.add_argument("--time-scale", type=float, default=0.02,
                    help="live backends: wall seconds per scenario second "
                    "(smaller = faster run, more concurrency jitter)")
    ap.add_argument("--payload", default="sleep",
                    choices=tuple(sorted(PAYLOADS)),
                    help="live backends: per-message PE payload")
    ap.add_argument("--measurement", choices=("emulated", "os"),
                    default="emulated",
                    help="multiproc backend: feed the profiler the sim's "
                    "emulated CPU draws (parity with the other backends) or "
                    "real per-message OS measurements from the worker "
                    "processes")
    ap.add_argument("--fail-worker", default=None, metavar="IDX:T",
                    help="inject a worker failure: kill worker IDX at "
                    "scenario time T seconds (sim and live backends; "
                    "in-flight messages requeue at the head, at-least-once)")
    ap.add_argument("--engine", choices=("object", "numpy", "auto"),
                    default=None,
                    help="packing engine override: per-bin object packers, "
                    "the array-backed numpy engine (decision-identical; "
                    "fast on large fleets), or auto (numpy above the "
                    "fleet-size threshold); default: the scenario's "
                    "allocator config")
    ap.add_argument("--seed", type=int, default=0, help="base stream seed")
    ap.add_argument("--runs", type=int, default=None,
                    help="override the scenario's run count")
    ap.add_argument("--t-max", type=float, default=None,
                    help="override the simulated-time cap (seconds)")
    ap.add_argument("--jobs", type=int, default=None,
                    help="worker processes for multi-policy sweeps (one "
                    "process per policy; default: min(#policies, CPUs); "
                    "1 forces the serial path)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-long run via the scenario's smoke overrides")
    ap.add_argument("--out", default=None,
                    help="directory for per-tick CSV + summary JSON")
    ap.add_argument("--obs-out", default=None, metavar="DIR",
                    help="enable the observability plane and export the "
                    "event log (events.jsonl), Prometheus text "
                    "(metrics.prom) and run summary (summary.json) to DIR; "
                    "single-policy runs only")
    ap.add_argument("--obs-level", choices=("lifecycle", "full"),
                    default="full",
                    help="with --obs-out: 'lifecycle' skips IRM "
                    "decision-audit events (irm.pack); 'full' records "
                    "everything")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if any expectation fails")
    args = ap.parse_args(argv)

    if args.list or not args.scenario:
        return _list(args)

    try:
        scn = get_scenario(args.scenario)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    stream_overrides = None
    t_max = args.t_max
    n_runs = args.runs
    if args.smoke:
        stream_overrides = scn.smoke_overrides
        if t_max is None:
            t_max = scn.smoke_t_max
        if n_runs is None:
            n_runs = 1
        _smoke_note(scn)

    if args.backend == "serving":
        from .serving import run_serving_scenario

        for flag, value in (("--policy", args.policy), ("--runs", args.runs),
                            ("--fail-worker", args.fail_worker),
                            ("--engine", args.engine),
                            ("--obs-out", args.obs_out),
                            ("--check", args.check or None)):
            if value is not None:
                print(f"note: {flag} does not apply to the serving backend "
                      "(admission is vector First-Fit; no sim expectations)",
                      file=sys.stderr)
        serving_kwargs = {}
        if t_max is not None:
            serving_kwargs["t_max"] = float(t_max)
        summary = run_serving_scenario(
            scn, seed=args.seed, stream_overrides=stream_overrides,
            **serving_kwargs,
        )
        eng = summary.pop("engine")
        print(f"\n=== scenario {scn.name!r} · backend serving ===")
        for k, v in summary.items():
            print(f"  {k}: {v:.4g}" if isinstance(v, float) else f"  {k}: {v}")
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            cols = ["t", "queue", "replicas", "target", "mean_slot_load",
                    "mean_page_load", "completed"]
            with open(os.path.join(args.out, f"{scn.name}_serving.csv"),
                      "w", newline="") as f:
                w = csv.writer(f)
                w.writerow(cols)
                for m in eng.metrics:
                    w.writerow([m[c] for c in cols])
            with open(os.path.join(args.out, f"{scn.name}_serving.json"), "w") as f:
                json.dump(summary, f, indent=2)
            print(f"\nartifacts written to {args.out}")
        return 0

    if args.policy in (None, ""):
        policies = [None]
    elif args.policy == "all":
        # the scenario's policy family: vector packers for multi-resource
        # clusters, the scalar Any-Fit group otherwise
        policies = list(policies_for(scn))
    else:
        policies = [p.strip() for p in args.policy.split(",") if p.strip()]

    sim_overrides = None
    if args.fail_worker is not None:
        try:
            idx_s, _, t_s = args.fail_worker.partition(":")
            idx, when = int(idx_s), float(t_s)
            if idx < 0:
                raise ValueError(idx)
            sim_overrides = {"fail_worker_at": (idx, when)}
        except ValueError:
            print(f"error: --fail-worker expects IDX:T with IDX >= 0, got "
                  f"{args.fail_worker!r}", file=sys.stderr)
            return 2

    run_kwargs = dict(base_seed=args.seed, n_runs=n_runs,
                      stream_overrides=stream_overrides, t_max=t_max,
                      backend=args.backend, sim_overrides=sim_overrides,
                      engine=args.engine)
    if args.obs_out is not None:
        if len(policies) > 1:
            print("error: --obs-out requires a single policy (the event "
                  "log is per-run)", file=sys.stderr)
            return 2
        from ..obs import ObsConfig

        run_kwargs["obs"] = ObsConfig(out=args.obs_out, level=args.obs_level)
    if args.backend in ("live", "multiproc"):
        from ..runtime.live import RuntimeConfig

        run_kwargs["runtime"] = RuntimeConfig(
            time_scale=args.time_scale,
            payload=args.payload,
            transport="multiproc" if args.backend == "multiproc" else "inproc",
            measurement=args.measurement,
        )
    elif args.measurement != "emulated":
        print("note: --measurement applies to the multiproc backend only",
              file=sys.stderr)
    try:
        if len(policies) > 1 and None not in policies:
            # policy sweep: one process per policy (IRM state is per-policy)
            results = sweep_policies(
                scn, policies, jobs=args.jobs, **run_kwargs
            )
        else:
            results = {p: run_scenario(scn, policy=p, **run_kwargs)
                       for p in policies}
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    failed = False
    all_summaries: Dict[str, Dict] = {}
    for result in results.values():
        _print_summary(result)
        failed |= not result.ok
        all_summaries[result.policy] = {
            "summary": result.summary,
            "expectations": result.expectations,
        }
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            _dump_tick_csv(
                os.path.join(args.out, f"{scn.name}_{result.policy}.csv"),
                result,
            )
    if args.out:
        with open(os.path.join(args.out, f"{scn.name}_summary.json"), "w") as f:
            json.dump(all_summaries, f, indent=2)
        print(f"\nartifacts written to {args.out}")

    if args.check and failed:
        print("\nFAILED: one or more expectations did not hold", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
