"""Scenario registry: a pluggable catalogue of (workload, cluster, checks).

A *scenario* bundles everything needed to evaluate the IRM on one traffic
shape:

  - a stream factory (``make_stream(seed, **overrides) -> Stream``),
  - the cluster configuration to run it on (``sim_config``),
  - the IRM configuration (``irm_config``) — the packing policy inside it is
    swept by the runner,
  - how many back-to-back runs the experiment takes (the profiler persists
    across runs, as in the paper's 10-run microscopy experiment),
  - expected-behavior assertions (``Expectation``) that encode the claims a
    scenario is supposed to exhibit (e.g. "load concentrates on low-index
    workers").

Scenarios are registered with the ``@register_scenario`` decorator on their
stream factory; the factory itself stays importable and directly callable.
Adding a workload to the repo is now one registered function — benchmarks,
examples, tests, and the ``python -m repro.scenarios.run`` CLI all pick it
up from here.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from ..core.irm import IRMConfig
from ..core.sim import SimConfig, SimResult
from .streams import Stream

__all__ = [
    "Expectation",
    "Scenario",
    "register_scenario",
    "get_scenario",
    "list_scenarios",
    "scenario_names",
    "unregister_scenario",
]


@dataclasses.dataclass(frozen=True)
class Expectation:
    """A named check over a finished run (the paper's per-figure claims)."""

    name: str
    description: str
    check: Callable[[SimResult], bool]

    def evaluate(self, result: SimResult) -> bool:
        return bool(self.check(result))


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One registered workload + cluster + expected behavior."""

    name: str
    description: str
    make_stream: Callable[..., Stream]
    sim_config: Callable[[], SimConfig] = SimConfig
    irm_config: Callable[[], IRMConfig] = IRMConfig
    # number of back-to-back runs; the IRM profiler persists across them
    # (run ``i`` streams with seed ``base_seed + i``)
    n_runs: int = 1
    tags: Tuple[str, ...] = ()
    expectations: Tuple[Expectation, ...] = ()
    # kwargs for make_stream that shrink the scenario to a seconds-long
    # deterministic run — used by tests and the CI smoke invocation
    smoke_overrides: Optional[Dict[str, object]] = None
    # sim-time cap to pair with smoke_overrides
    smoke_t_max: Optional[float] = None
    # execution backends this scenario supports (``run_scenario`` rejects
    # others; ``--list`` prints the set).  Default: every backend — a
    # scenario narrows this only when its semantics genuinely require one
    # engine (e.g. a wall-clock-calibration scenario that is sim-only).
    backends: Tuple[str, ...] = ("sim", "live", "multiproc", "serving")

    def stream(self, seed: int = 0, **overrides: object) -> Stream:
        return self.make_stream(seed, **overrides)


_SCENARIOS: Dict[str, Scenario] = {}


def register_scenario(
    name: str,
    description: str,
    *,
    sim_config: Callable[[], SimConfig] = SimConfig,
    irm_config: Callable[[], IRMConfig] = IRMConfig,
    n_runs: int = 1,
    tags: Tuple[str, ...] = (),
    expectations: Tuple[Expectation, ...] = (),
    smoke_overrides: Optional[Dict[str, object]] = None,
    smoke_t_max: Optional[float] = None,
    backends: Tuple[str, ...] = ("sim", "live", "multiproc", "serving"),
) -> Callable[[Callable[..., Stream]], Callable[..., Stream]]:
    """Decorator: register a stream factory as a named scenario.

    The decorated function is returned unchanged, so it remains a plain
    importable generator; the registry holds a ``Scenario`` wrapping it.
    """

    def deco(fn: Callable[..., Stream]) -> Callable[..., Stream]:
        if name in _SCENARIOS:
            raise ValueError(f"scenario {name!r} is already registered")
        _SCENARIOS[name] = Scenario(
            name=name,
            description=description,
            make_stream=fn,
            sim_config=sim_config,
            irm_config=irm_config,
            n_runs=n_runs,
            tags=tuple(tags),
            expectations=tuple(expectations),
            smoke_overrides=dict(smoke_overrides) if smoke_overrides else None,
            smoke_t_max=smoke_t_max,
            backends=tuple(backends),
        )
        return fn

    return deco


def _ensure_library_loaded() -> None:
    # The built-in scenarios register on import; defer it so the registry
    # module itself stays import-cycle-free.
    from . import library  # noqa: F401


def get_scenario(name: str) -> Scenario:
    _ensure_library_loaded()
    try:
        return _SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; registered: {sorted(_SCENARIOS)}"
        ) from None


def list_scenarios() -> List[Scenario]:
    """All registered scenarios, sorted by name."""
    _ensure_library_loaded()
    return [_SCENARIOS[k] for k in sorted(_SCENARIOS)]


def scenario_names() -> List[str]:
    _ensure_library_loaded()
    return sorted(_SCENARIOS)


def unregister_scenario(name: str) -> None:
    """Remove a scenario (used by tests registering throwaway scenarios)."""
    _SCENARIOS.pop(name, None)
