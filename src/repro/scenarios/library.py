"""Built-in scenarios: the paper's two evaluations plus four extended shapes.

Each registration pins the workload generator to the cluster configuration
the experiment runs on and to the claims it is expected to exhibit.  The
``synthetic`` and ``microscopy`` scenarios reproduce the paper's Section VI
setups bit-for-bit (same generators, same SNIC-testbed sim parameters the
seed benchmarks used); the other four cover the traffic shapes the
elasticity literature says an autoscaler must be judged on: spike trains,
diurnal cycles, heavy tails, and multi-tenant image mixes.

To add a scenario: write a ``(seed, **knobs) -> Stream`` generator (or
import one from ``streams``), decorate it with ``@register_scenario``, and
give it a ``smoke_overrides`` so tests and CI can run it in seconds.  See
docs/ARCHITECTURE.md for the full authoring guide.
"""

from __future__ import annotations

import numpy as np

from ..core.irm import IRMConfig
from ..core.sim import SimConfig, SimResult
from .engine import ACTIVE_THRESHOLD
from .registry import Expectation, register_scenario
from . import streams

__all__ = ["PAPER_SIM", "PAPER_SIM_USECASE", "MEM_SIM", "ACCEL_SIM",
           "VECTOR_IRM"]


def PAPER_SIM() -> SimConfig:
    """The SNIC testbed model used for the paper's synthetic runs."""
    return SimConfig(
        dt=0.5, cores_per_worker=8, max_workers=5,
        worker_boot_delay=15.0, pe_start_delay=2.5,
        container_idle_timeout=1.0, report_interval=1.0,
        t_max=1500.0, seed=0,
    )


def PAPER_SIM_USECASE() -> SimConfig:
    """Same testbed with the use case's longer horizon (767 images)."""
    cfg = PAPER_SIM()
    cfg.t_max = 3600.0
    return cfg


def MEM_SIM() -> SimConfig:
    """The SNIC testbed with a rigid memory dimension per worker."""
    cfg = PAPER_SIM_USECASE()
    cfg.resource_dims = ("cpu", "mem")
    return cfg


def ACCEL_SIM() -> SimConfig:
    """The testbed with one accelerator per worker as a rigid dimension."""
    cfg = PAPER_SIM()
    cfg.resource_dims = ("cpu", "accel")
    return cfg


def VECTOR_IRM() -> IRMConfig:
    """IRM configured for vector bin-packing (paper Sec. VII direction)."""
    cfg = IRMConfig()
    cfg.allocator.algorithm = "vector-first-fit"
    return cfg


# ---------------------------------------------------------------------------
# Shared expectation checks
# ---------------------------------------------------------------------------


def _completes(res: SimResult) -> bool:
    return res.completed == res.total


def _nearly_completes(res: SimResult) -> bool:
    """>= 99% processed.

    The paper's threshold predictor can starve a sub-threshold tail: a
    backlog smaller than ``queue_low`` with ~zero rate-of-change never
    triggers a scale-up (all four cases miss), so the last stragglers of a
    trickle can sit in the queue forever once their PEs idle out.  The
    synthetic run reproduces this faithfully (292/293 at t_max).
    """
    return res.completed >= 0.99 * res.total


def _capacity_respected(res: SimResult) -> bool:
    return bool((res.scheduled_cpu <= 1.0 + 1e-9).all())


def _low_index_concentration(res: SimResult) -> bool:
    """Fig. 3: 'the workload is focused toward the lower index workers'."""
    per_worker = res.scheduled_cpu.sum(axis=0)
    w = len(per_worker)
    return bool(
        per_worker.argmax() == 0
        and per_worker[: w // 2 + 1].sum() > per_worker[w // 2 + 1:].sum()
    )


def _error_centered(res: SimResult) -> bool:
    """Fig. 5: scheduled-vs-measured error is noisy but centered near zero."""
    active = res.scheduled_cpu > ACTIVE_THRESHOLD
    err = res.error[active]
    return bool(abs(err.mean()) < 15.0) if err.size else True


def _workers_filled_before_spill(res: SimResult) -> bool:
    """Fig. 8: a worker opens only when the lower-index ones are ~full."""
    ok = []
    for w in range(1, res.scheduled_cpu.shape[1]):
        started = res.scheduled_cpu[:, w] > ACTIVE_THRESHOLD
        if started.any():
            t_first = int(np.argmax(started))
            ok.append(float(res.scheduled_cpu[t_first, :w].min()) > 0.7)
    return bool(ok and all(ok))


def _target_exceeds_cap(res: SimResult) -> bool:
    """Fig. 10: the IRM keeps requesting workers beyond the cap."""
    return bool(res.target_workers.max() > res.active_workers.max())


def _scales_up_and_down(res: SimResult) -> bool:
    """The pool grows under pressure and shrinks as the backlog drains."""
    peak = int(res.pe_count.max())
    return peak >= 3 and int(res.pe_count[-1]) < peak


def _queue_spikes(res: SimResult) -> bool:
    return bool(res.queue_len.max() >= 8)


def _multiple_images_served(res: SimResult) -> bool:
    return len({m.image for m in res.messages}) >= 3


COMPLETES = Expectation(
    "completes", "every streamed message is processed", _completes
)
CAPACITY = Expectation(
    "capacity_respected", "scheduled load never exceeds worker capacity",
    _capacity_respected,
)


# ---------------------------------------------------------------------------
# The paper's two scenarios (Section VI)
# ---------------------------------------------------------------------------

register_scenario(
    "synthetic",
    "Paper Sec. VI-A: regular small batches + two large peaks, four "
    "single-core job classes (5/10/20/40 s).",
    sim_config=PAPER_SIM,
    tags=("paper", "synthetic"),
    expectations=(
        Expectation(
            "nearly_completes",
            ">= 99% of messages processed (the threshold predictor starves "
            "sub-queue_low tails — faithful paper behavior)",
            _nearly_completes,
        ),
        CAPACITY,
        Expectation(
            "low_index_concentration",
            "Fig. 3: load concentrates on low-index workers",
            _low_index_concentration,
        ),
        Expectation(
            "error_centered",
            "Fig. 5: scheduled-vs-measured error centered near zero",
            _error_centered,
        ),
    ),
    smoke_overrides={
        "t_end": 60.0, "peak_times": (30.0,), "peak_size": 8,
        "batch_size": (2, 4),
    },
    smoke_t_max=600.0,
)(streams.synthetic_workload)


register_scenario(
    "microscopy",
    "Paper Sec. VI-B: 767 CellProfiler microscopy images streamed as one "
    "batch, 10-20 s each; 10 runs with a persistent profiler.",
    sim_config=PAPER_SIM_USECASE,
    n_runs=10,
    tags=("paper", "usecase"),
    expectations=(
        COMPLETES,
        CAPACITY,
        Expectation(
            "workers_filled_before_spill",
            "Fig. 8: workers reach ~100% before the next one opens",
            _workers_filled_before_spill,
        ),
        Expectation(
            "target_exceeds_cap",
            "Fig. 10: the IRM requests more workers than the cap allows",
            _target_exceeds_cap,
        ),
    ),
    smoke_overrides={"n_images": 40, "duration_range": (4.0, 8.0)},
    smoke_t_max=600.0,
)(streams.usecase_workload)


# ---------------------------------------------------------------------------
# Extended traffic shapes
# ---------------------------------------------------------------------------

register_scenario(
    "bursty",
    "Spike trains: a thin Poisson trickle punctuated by large random "
    "bursts — the adversarial case for queue-ROC prediction.",
    sim_config=PAPER_SIM,
    tags=("extended", "bursty"),
    expectations=(
        COMPLETES,
        CAPACITY,
        Expectation(
            "queue_spikes", "bursts show up as backlog spikes", _queue_spikes
        ),
        Expectation(
            "scales_up_and_down",
            "the PE pool grows under a burst and shrinks after",
            _scales_up_and_down,
        ),
    ),
    smoke_overrides={
        "t_end": 60.0, "burst_rate": 1.0 / 30.0, "burst_size": (8, 12),
        "duration_range": (3.0, 8.0),
    },
    smoke_t_max=600.0,
)(streams.bursty_workload)


register_scenario(
    "diurnal",
    "Diurnal sinusoid: arrival rate rides a compressed day/night cycle; "
    "the pool must track the curve without thrashing.",
    sim_config=PAPER_SIM,
    tags=("extended", "diurnal"),
    expectations=(
        COMPLETES,
        CAPACITY,
        Expectation(
            "scales_up_and_down",
            "the PE pool follows the traffic curve up and back down",
            _scales_up_and_down,
        ),
    ),
    smoke_overrides={
        "t_end": 120.0, "period": 60.0, "peak_arrivals_per_s": 0.8,
        "duration_range": (3.0, 8.0),
    },
    smoke_t_max=700.0,
)(streams.diurnal_workload)


register_scenario(
    "heavy-tailed",
    "Pareto service times: most messages quick, a few 10-30x longer — the "
    "stress case for the profiler's mean-based size estimates.",
    sim_config=PAPER_SIM,
    tags=("extended", "heavy-tailed"),
    expectations=(COMPLETES, CAPACITY),
    smoke_overrides={
        "n_messages": 40, "t_end": 60.0, "duration_cap": 30.0,
    },
    smoke_t_max=700.0,
)(streams.heavy_tailed_workload)


# ---------------------------------------------------------------------------
# Multi-resource scenarios (vector bin-packing — paper Sec. VII future work)
# ---------------------------------------------------------------------------


def _dims_capacity_respected(res: SimResult) -> bool:
    """No dimension of any worker is ever scheduled above capacity."""
    if res.scheduled_res is None:
        return False  # a multi-resource scenario must record per-dim loads
    return bool((res.scheduled_res <= 1.0 + 1e-9).all())


def _memory_is_bottleneck(res: SimResult) -> bool:
    """Memory saturates workers while their CPU stays far from full."""
    if res.scheduled_res is None:
        return False
    d = res.resource_dims.index("mem")
    mem = res.scheduled_res[:, :, d]
    cpu = res.scheduled_res[:, :, 0]
    hot = mem > 0.5
    if not hot.any():
        return False
    # wherever memory is half-committed, CPU is never the tighter dimension
    # (<=: the cold-start default estimate is equal in every dimension), and
    # memory carries well over the CPU's total scheduled load
    return bool(
        (cpu[hot] <= mem[hot] + 1e-9).all() and mem.sum() > 1.5 * cpu.sum()
    )


def _accel_and_cpu_colocated(res: SimResult) -> bool:
    """Vector packing co-locates accelerator and CPU tenants on one worker."""
    if res.scheduled_res is None:
        return False
    d = res.resource_dims.index("accel")
    accel = res.scheduled_res[:, :, d]
    cpu = res.scheduled_res[:, :, 0]
    return bool(((accel > 0.2) & (cpu > 0.3)).any())


register_scenario(
    "microscopy-mem",
    "Memory-bound microscopy: each analysis pins 1 core but holds 25-45% "
    "of worker RAM — memory, not CPU, dictates the packing.",
    sim_config=MEM_SIM,
    irm_config=VECTOR_IRM,
    n_runs=3,
    tags=("extended", "vector", "usecase"),
    expectations=(
        COMPLETES,
        CAPACITY,
        Expectation(
            "dims_capacity_respected",
            "no worker dimension is scheduled above capacity",
            _dims_capacity_respected,
        ),
        Expectation(
            "memory_is_bottleneck",
            "memory saturates workers while CPU stays slack",
            _memory_is_bottleneck,
        ),
    ),
    smoke_overrides={"n_images": 30, "duration_range": (4.0, 8.0)},
    smoke_t_max=600.0,
)(streams.microscopy_mem_workload)


register_scenario(
    "mixed-accel",
    "Mixed CPU/accelerator tenants: multi-core ETL jobs interleave with "
    "accelerator-hungry inference — complementary vector items.",
    sim_config=ACCEL_SIM,
    irm_config=VECTOR_IRM,
    tags=("extended", "vector", "multi-tenant"),
    expectations=(
        COMPLETES,
        CAPACITY,
        Expectation(
            "dims_capacity_respected",
            "no worker dimension is scheduled above capacity",
            _dims_capacity_respected,
        ),
        Expectation(
            "accel_and_cpu_colocated",
            "accelerator and CPU tenants share a worker at least once",
            _accel_and_cpu_colocated,
        ),
        Expectation(
            "multiple_images_served",
            "at least three tenant images are processed",
            _multiple_images_served,
        ),
    ),
    smoke_overrides={"t_end": 80.0, "batch_size": (2, 5)},
    smoke_t_max=700.0,
)(streams.mixed_accel_workload)


register_scenario(
    "multi-tenant",
    "Multi-image mix: four tenants with different durations and CPU "
    "draws — the packer must handle genuinely heterogeneous item sizes.",
    sim_config=PAPER_SIM,
    tags=("extended", "multi-tenant"),
    expectations=(
        COMPLETES,
        CAPACITY,
        Expectation(
            "multiple_images_served",
            "at least three tenant images are processed",
            _multiple_images_served,
        ),
    ),
    smoke_overrides={"t_end": 60.0, "batch_size": (2, 5)},
    smoke_t_max=600.0,
)(streams.multi_tenant_workload)
