"""Serving-backend adapter: drive scenario streams through the LLM engine.

The IRM control plane has two ``ClusterView``-style implementations: the
discrete-event cluster sim (``core/sim.py``) and the continuous-batching
serving engine (``serving/engine.py``).  This module maps a scenario's
``Stream`` onto the second one so the *same* registered workloads exercise
both backends:

  stream message  -> inference request (duration -> token counts)
  container image -> request class (the profiler key)
  batch arrival t -> request arrival time (optionally time-compressed)

The mapping is deliberately monotone — a message that runs 2x longer in the
cluster sim asks for 2x the decode tokens here — so a traffic shape keeps
its character (bursts stay bursts, heavy tails stay heavy) across backends.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from ..serving.engine import EngineConfig, ReplicaConfig, Request, ServingEngine
from .registry import Scenario, get_scenario
from .streams import Stream

__all__ = ["stream_to_requests", "run_serving_scenario", "default_engine_config"]


def default_engine_config(max_replicas: int = 5) -> EngineConfig:
    """The serving analogue of the paper's 5-worker SNIC cap."""
    return EngineConfig(
        replica=ReplicaConfig(
            max_slots=8, kv_pages=1024, page_size=16,
            prefill_tokens_per_s=80_000.0, decode_tokens_per_s=6_000.0,
            spinup_delay=5.0,
        ),
        max_replicas=max_replicas,
        dt=0.1,
    )


def stream_to_requests(
    stream: Stream,
    *,
    prompt_tokens_per_s: float = 60.0,
    decode_tokens_per_s: float = 16.0,
    min_prompt: int = 32,
    min_new_tokens: int = 16,
    time_scale: float = 1.0,
    mem_prompt_weight: float = 2.0,
    accel_decode_weight: float = 2.0,
) -> List[Tuple[float, Request]]:
    """Convert a workload stream into a time-ordered request schedule.

    A message of ``duration`` seconds becomes a request with prompt and
    decode lengths proportional to that duration, so per-class cost
    heterogeneity survives the translation.  ``time_scale`` compresses the
    arrival axis (the serving engine processes a "10 s" request in well
    under a second of engine time).

    Multi-resource messages map onto the replica's own vector dimensions:
    memory demand scales the *prompt* (KV pages are the serving engine's
    memory dimension), accelerator demand scales the *decode* length
    (slot-seconds are its accelerator-time dimension).  A message with no
    ``resources`` maps exactly as before.
    """
    schedule: List[Tuple[float, Request]] = []
    for t, msgs in sorted(stream.batches, key=lambda b: b[0]):
        for m in msgs:
            prompt_s = m.duration * prompt_tokens_per_s
            decode_s = m.duration * decode_tokens_per_s
            if m.resources:
                prompt_s *= 1.0 + mem_prompt_weight * m.resources.get("mem", 0.0)
                decode_s *= 1.0 + accel_decode_weight * m.resources.get("accel", 0.0)
            schedule.append(
                (
                    t * time_scale,
                    Request(
                        prompt_len=max(min_prompt, int(prompt_s)),
                        max_new_tokens=max(min_new_tokens, int(decode_s)),
                        req_class=m.image,
                    ),
                )
            )
    schedule.sort(key=lambda x: x[0])
    return schedule


def run_serving_scenario(
    scenario: Union[str, Scenario],
    *,
    seed: int = 0,
    stream_overrides: Optional[Dict[str, object]] = None,
    engine_cfg: Optional[EngineConfig] = None,
    time_scale: float = 0.25,
    t_max: float = 1200.0,
    request_kwargs: Optional[Dict[str, float]] = None,
) -> Dict[str, object]:
    """Run one scenario's stream through the simulated serving backend.

    ``request_kwargs`` is forwarded to ``stream_to_requests`` (token-count
    mapping knobs).  Returns the engine summary extended with queue/replica
    statistics; the engine itself is included under ``"engine"`` for
    callers that want the raw per-tick metrics.
    """
    scn = get_scenario(scenario) if isinstance(scenario, str) else scenario
    stream = scn.make_stream(seed, **(stream_overrides or {}))
    schedule = stream_to_requests(
        stream, time_scale=time_scale, **(request_kwargs or {})
    )
    eng = ServingEngine(engine_cfg or default_engine_config())

    idx = 0
    while eng.t < t_max:
        while idx < len(schedule) and schedule[idx][0] <= eng.t:
            eng.submit(schedule[idx][1])
            idx += 1
        eng.step()
        if idx >= len(schedule) and not eng.queue and all(
            not r.active and not r.prefilling
            for r in eng.backend.replicas
            if not r.retired
        ):
            break

    replicas = np.array([m["replicas"] for m in eng.metrics]) if eng.metrics else np.array([1])
    queue = np.array([m["queue"] for m in eng.metrics]) if eng.metrics else np.array([0])
    summary: Dict[str, object] = dict(eng.summary())
    summary.update(
        {
            "scenario": scn.name,
            "backend": "serving",
            "submitted": len(schedule),
            "peak_replicas": int(replicas.max()),
            "final_replicas": int(replicas[-1]),
            "peak_queue_len": int(queue.max()),
            "engine": eng,
        }
    )
    return summary
