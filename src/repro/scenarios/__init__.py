"""Scenario engine: pluggable workloads + cluster configs + expectations.

``streams`` (the generators and the ``Message``/``Stream`` types) is
imported eagerly and has no dependency on the rest of the package —
``repro.core.workloads`` re-exports from it, so everything else here loads
lazily (PEP 562) to keep that edge acyclic.

Public surface:

  - ``Message``, ``Stream`` and the stream generators (``streams``),
  - ``Scenario``, ``Expectation``, ``register_scenario``, ``get_scenario``,
    ``list_scenarios``, ``scenario_names`` (``registry``),
  - ``run_scenario``, ``sweep_policies``, ``ScenarioResult``,
    ``summarize_result``, ``POLICIES`` (``engine``),
  - ``run_serving_scenario``, ``stream_to_requests`` (``serving``),
  - the built-in catalogue registers on first registry access (``library``).

CLI: ``PYTHONPATH=src python -m repro.scenarios.run --list``.
"""

from .streams import (
    Message,
    Stream,
    bursty_workload,
    diurnal_workload,
    heavy_tailed_workload,
    microscopy_mem_workload,
    mixed_accel_workload,
    multi_tenant_workload,
    synthetic_workload,
    usecase_workload,
)

_LAZY = {
    "Expectation": "registry",
    "Scenario": "registry",
    "register_scenario": "registry",
    "get_scenario": "registry",
    "list_scenarios": "registry",
    "scenario_names": "registry",
    "unregister_scenario": "registry",
    "ScenarioResult": "engine",
    "run_scenario": "engine",
    "sweep_policies": "engine",
    "summarize_result": "engine",
    "policies_for": "engine",
    "POLICIES": "engine",
    "VECTOR_POLICIES": "engine",
    "run_serving_scenario": "serving",
    "stream_to_requests": "serving",
    "default_engine_config": "serving",
}

__all__ = [
    "Message",
    "Stream",
    "synthetic_workload",
    "usecase_workload",
    "bursty_workload",
    "diurnal_workload",
    "heavy_tailed_workload",
    "microscopy_mem_workload",
    "mixed_accel_workload",
    "multi_tenant_workload",
    *_LAZY,
]


def __getattr__(name: str):
    try:
        module = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}"
        ) from None
    import importlib

    mod = importlib.import_module(f".{module}", __name__)
    value = getattr(mod, name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(__all__)
