"""Stream generators for the scenario engine.

The paper evaluates its bin-packing IRM on exactly two workloads: the
Section VI-A synthetic batches and the Section VI-B 767-image microscopy
use case.  The resource-elasticity literature (de Assunção et al.,
1709.01363; Will et al., 2501.14456) shows that autoscaler quality is only
measurable across *diverse* traffic shapes, so this module also provides
bursty spike trains, a diurnal sinusoid, heavy-tailed (Pareto) service
times, and multi-tenant image mixes.

Every generator is a pure function ``(seed, **knobs) -> Stream`` with no
dependency on the rest of the package; the scenario registry
(``repro.scenarios.registry``) wraps them with cluster configs and
expected-behavior assertions.  ``repro.core.workloads`` re-exports the
paper's two generators for backward compatibility.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Message",
    "Stream",
    "synthetic_workload",
    "usecase_workload",
    "bursty_workload",
    "diurnal_workload",
    "heavy_tailed_workload",
    "multi_tenant_workload",
    "microscopy_mem_workload",
    "mixed_accel_workload",
]

_msg_ids = itertools.count()


@dataclasses.dataclass
class Message:
    """One stream message: data to process + the container image to run.

    ``cpu_cores`` is the CPU draw while processing, in cores; ``duration`` is
    the processing time in seconds.  ``resources`` optionally carries the
    draw on *auxiliary* worker dimensions while busy (e.g. ``{"mem": 0.3}``
    = 30% of a worker's memory), as fractions of one worker; CPU stays in
    ``cpu_cores``.  ``None`` is the paper's scalar CPU-only model.
    """

    image: str
    duration: float
    cpu_cores: float = 1.0
    arrival: float = 0.0
    resources: Optional[Dict[str, float]] = None
    msg_id: int = dataclasses.field(default_factory=lambda: next(_msg_ids))
    # bookkeeping filled in by the sim
    start_t: float = -1.0
    done_t: float = -1.0


@dataclasses.dataclass
class Stream:
    """A time-ordered schedule of message batches."""

    batches: List[Tuple[float, List[Message]]]

    @property
    def num_messages(self) -> int:
        return sum(len(msgs) for _, msgs in self.batches)

    @property
    def images(self) -> List[str]:
        seen: Dict[str, None] = {}
        for _, msgs in self.batches:
            for m in msgs:
                seen.setdefault(m.image, None)
        return list(seen)

    def horizon(self) -> float:
        return max(t for t, _ in self.batches) if self.batches else 0.0


# ---------------------------------------------------------------------------
# The paper's two workloads (Section VI)
# ---------------------------------------------------------------------------


def synthetic_workload(
    seed: int = 0,
    *,
    t_end: float = 480.0,
    batch_interval: float = 12.0,
    batch_size: Tuple[int, int] = (3, 7),
    peak_times: Tuple[float, ...] = (120.0, 330.0),
    peak_size: int = 48,
) -> Stream:
    """Paper Section VI-A: periodic small batches plus two large peaks.

    Four synthetic classes all busy one core at ~100%, with durations
    5 / 10 / 20 / 40 s ("various amounts of time").
    """
    rng = np.random.default_rng(seed)
    classes = [
        ("synthetic/cpu100-d5", 5.0),
        ("synthetic/cpu100-d10", 10.0),
        ("synthetic/cpu100-d20", 20.0),
        ("synthetic/cpu100-d40", 40.0),
    ]

    def make_msgs(n: int, t: float) -> List[Message]:
        idx = rng.integers(0, len(classes), size=n)
        out = []
        for i in idx:
            image, dur = classes[int(i)]
            jitter = float(rng.uniform(0.9, 1.1))
            out.append(
                Message(image=image, duration=dur * jitter, cpu_cores=1.0, arrival=t)
            )
        return out

    batches: List[Tuple[float, List[Message]]] = []
    t = 0.0
    while t < t_end:
        n = int(rng.integers(batch_size[0], batch_size[1] + 1))
        batches.append((t, make_msgs(n, t)))
        t += batch_interval
    for pt in peak_times:
        batches.append((pt, make_msgs(peak_size, pt)))
    batches.sort(key=lambda b: b[0])
    return Stream(batches=batches)


def usecase_workload(
    seed: int = 0,
    *,
    n_images: int = 767,
    duration_range: Tuple[float, float] = (10.0, 20.0),
    image: str = "haste/cellprofiler:3.1.9",
) -> Stream:
    """Paper Section VI-B: the CellProfiler microscopy batch.

    The entire collection is streamed as a single batch; per-image analysis
    takes 10–20 s ("Due to variations in the images they take varying
    amounts of time to process").  The streaming order is randomized per run
    (the ``seed``).
    """
    rng = np.random.default_rng(seed)
    durations = rng.uniform(duration_range[0], duration_range[1], size=n_images)
    rng.shuffle(durations)  # randomized streaming order
    msgs = [
        Message(image=image, duration=float(d), cpu_cores=1.0, arrival=0.0)
        for d in durations
    ]
    return Stream(batches=[(0.0, msgs)])


# ---------------------------------------------------------------------------
# Extended traffic shapes (beyond the paper)
# ---------------------------------------------------------------------------


def bursty_workload(
    seed: int = 0,
    *,
    t_end: float = 480.0,
    trickle_interval: float = 8.0,
    trickle_size: Tuple[int, int] = (1, 3),
    burst_rate: float = 1.0 / 90.0,
    burst_size: Tuple[int, int] = (24, 56),
    burst_times: Optional[Tuple[float, ...]] = None,
    duration_range: Tuple[float, float] = (5.0, 20.0),
    image: str = "bursty/worker",
) -> Stream:
    """Spike trains: a thin Poisson trickle punctuated by large random bursts.

    Bursts arrive as a Poisson process of rate ``burst_rate`` (per second) —
    or at the fixed ``burst_times`` when given (the paper's deterministic
    two-peak shape); each dumps a uniform-random number of messages at once.
    This is the adversarial case for a queue-ROC load predictor: pressure
    jumps from ~0 to tens of messages inside one read interval.
    """
    rng = np.random.default_rng(seed)
    batches: List[Tuple[float, List[Message]]] = []

    def make_msgs(n: int, t: float) -> List[Message]:
        durs = rng.uniform(duration_range[0], duration_range[1], size=n)
        return [
            Message(image=image, duration=float(d), cpu_cores=1.0, arrival=t)
            for d in durs
        ]

    t = 0.0
    while t < t_end:
        n = int(rng.integers(trickle_size[0], trickle_size[1] + 1))
        batches.append((t, make_msgs(n, t)))
        t += trickle_interval
    if burst_times is not None:
        for bt in burst_times:
            n = int(rng.integers(burst_size[0], burst_size[1] + 1))
            batches.append((float(bt), make_msgs(n, float(bt))))
    else:
        # Poisson burst arrivals
        t = float(rng.exponential(1.0 / burst_rate))
        while t < t_end:
            n = int(rng.integers(burst_size[0], burst_size[1] + 1))
            batches.append((t, make_msgs(n, t)))
            t += float(rng.exponential(1.0 / burst_rate))
    batches.sort(key=lambda b: b[0])
    return Stream(batches=batches)


def diurnal_workload(
    seed: int = 0,
    *,
    t_end: float = 600.0,
    period: float = 300.0,
    batch_interval: float = 5.0,
    peak_arrivals_per_s: float = 1.2,
    base_arrivals_per_s: float = 0.1,
    duration_range: Tuple[float, float] = (4.0, 12.0),
    image: str = "diurnal/worker",
) -> Stream:
    """Diurnal sinusoid: arrival rate follows a day/night cycle.

    The per-batch message count is Poisson with mean
    ``base + (peak - base) * (1 + sin) / 2`` integrated over the batch
    interval — a compressed version of the daily traffic curve every
    production autoscaler has to ride without thrashing.
    """
    rng = np.random.default_rng(seed)
    batches: List[Tuple[float, List[Message]]] = []
    t = 0.0
    while t < t_end:
        phase = (1.0 + math.sin(2.0 * math.pi * t / period - math.pi / 2.0)) / 2.0
        rate = base_arrivals_per_s + (peak_arrivals_per_s - base_arrivals_per_s) * phase
        n = int(rng.poisson(rate * batch_interval))
        if n > 0:
            durs = rng.uniform(duration_range[0], duration_range[1], size=n)
            batches.append(
                (
                    t,
                    [
                        Message(image=image, duration=float(d), arrival=t)
                        for d in durs
                    ],
                )
            )
        t += batch_interval
    return Stream(batches=batches)


def heavy_tailed_workload(
    seed: int = 0,
    *,
    n_messages: int = 400,
    t_end: float = 300.0,
    batch_interval: float = 6.0,
    pareto_shape: float = 1.6,
    duration_scale: float = 4.0,
    duration_cap: float = 120.0,
    image: str = "pareto/worker",
) -> Stream:
    """Heavy-tailed service times: Pareto-distributed durations.

    Most messages are quick, a few run 10-30x longer (capped at
    ``duration_cap``).  Mean-based size profiles systematically underestimate
    the tail, so this is the stress case for the profiler's moving average —
    the failure mode the elasticity surveys flag for percentile-blind
    autoscalers.
    """
    rng = np.random.default_rng(seed)
    durations = np.minimum(
        duration_scale * (1.0 + rng.pareto(pareto_shape, size=n_messages)),
        duration_cap,
    )
    n_batches = max(1, int(t_end / batch_interval))
    per_batch = np.array_split(durations, n_batches)
    batches: List[Tuple[float, List[Message]]] = []
    for i, chunk in enumerate(per_batch):
        t = i * batch_interval
        if len(chunk) == 0:
            continue
        batches.append(
            (
                t,
                [
                    Message(image=image, duration=float(d), arrival=t)
                    for d in chunk
                ],
            )
        )
    return Stream(batches=batches)


def microscopy_mem_workload(
    seed: int = 0,
    *,
    n_images: int = 300,
    duration_range: Tuple[float, float] = (10.0, 20.0),
    mem_range: Tuple[float, float] = (0.25, 0.45),
    image: str = "haste/cellprofiler-bigimg:3.1.9",
) -> Stream:
    """Memory-bound microscopy: the use case with large image working sets.

    Each analysis pins one core (a small CPU fraction on an 8-core worker)
    but holds a working set of 25-45% of a worker's memory while busy, so
    *memory* is the dominant dimension: a worker fits ~2-3 concurrent
    analyses by RAM long before its CPU fills.  A CPU-only packer would
    schedule 8 PEs per worker and overcommit memory ~3x; the vector packer
    opens workers on the memory dimension instead.
    """
    rng = np.random.default_rng(seed)
    durations = rng.uniform(duration_range[0], duration_range[1], size=n_images)
    mems = rng.uniform(mem_range[0], mem_range[1], size=n_images)
    rng.shuffle(durations)  # randomized streaming order (as the use case)
    msgs = [
        Message(
            image=image,
            duration=float(d),
            cpu_cores=1.0,
            arrival=0.0,
            resources={"mem": float(mem)},
        )
        for d, mem in zip(durations, mems, strict=True)
    ]
    return Stream(batches=[(0.0, msgs)])


def mixed_accel_workload(
    seed: int = 0,
    *,
    t_end: float = 360.0,
    batch_interval: float = 10.0,
    batch_size: Tuple[int, int] = (3, 8),
    tenants: Sequence[Tuple[str, float, float, float]] = (
        # (image, mean duration s, cpu cores busy, accel fraction busy)
        ("tenant-cpu/etl", 8.0, 4.0, 0.0),
        ("tenant-cpu/report", 5.0, 2.0, 0.0),
        ("tenant-accel/vision", 12.0, 0.8, 0.5),
        ("tenant-accel/asr", 6.0, 0.5, 0.25),
    ),
    tenant_weights: Tuple[float, ...] = (0.35, 0.25, 0.25, 0.15),
) -> Stream:
    """Mixed CPU / accelerator tenants sharing one worker pool.

    CPU tenants draw several cores and no accelerator; accelerator tenants
    draw a large accelerator fraction but little CPU.  The two are
    *complementary*: a vector packer can co-locate one vision job (accel
    0.5, cpu 0.1) with ETL jobs (cpu 0.5, accel 0) on the same worker,
    which no single-dimension formulation can even express.
    """
    rng = np.random.default_rng(seed)
    weights = np.asarray(tenant_weights, dtype=float)
    weights = weights / weights.sum()
    batches: List[Tuple[float, List[Message]]] = []
    t = 0.0
    while t < t_end:
        n = int(rng.integers(batch_size[0], batch_size[1] + 1))
        picks = rng.choice(len(tenants), size=n, p=weights)
        msgs = []
        for p in picks:
            image, mean_dur, cores, accel = tenants[int(p)]
            dur = float(rng.uniform(0.7, 1.3)) * mean_dur
            msgs.append(
                Message(
                    image=image,
                    duration=dur,
                    cpu_cores=cores,
                    arrival=t,
                    resources={"accel": accel} if accel > 0 else None,
                )
            )
        batches.append((t, msgs))
        t += batch_interval
    return Stream(batches=batches)


def multi_tenant_workload(
    seed: int = 0,
    *,
    t_end: float = 360.0,
    batch_interval: float = 10.0,
    batch_size: Tuple[int, int] = (4, 10),
    tenants: Sequence[Tuple[str, float, float]] = (
        # (image, mean duration s, cpu cores while busy)
        ("tenant-a/etl", 6.0, 1.0),
        ("tenant-b/ml-inference", 15.0, 1.0),
        ("tenant-c/thumbnailer", 3.0, 0.5),
        ("tenant-d/video-transcode", 30.0, 2.0),
    ),
    tenant_weights: Tuple[float, ...] = (0.4, 0.3, 0.2, 0.1),
) -> Stream:
    """Multi-image / multi-tenant mix: several container images per batch.

    Each tenant has its own image, mean duration, and CPU draw, so the
    profiler must learn one size per image and the packer must pack items of
    genuinely different sizes — the regime where First-Fit's 1.7 ratio
    actually matters (all-equal items make every Any-Fit algorithm trivial).
    """
    rng = np.random.default_rng(seed)
    weights = np.asarray(tenant_weights, dtype=float)
    weights = weights / weights.sum()
    batches: List[Tuple[float, List[Message]]] = []
    t = 0.0
    while t < t_end:
        n = int(rng.integers(batch_size[0], batch_size[1] + 1))
        picks = rng.choice(len(tenants), size=n, p=weights)
        msgs = []
        for p in picks:
            image, mean_dur, cores = tenants[int(p)]
            dur = float(rng.uniform(0.7, 1.3)) * mean_dur
            msgs.append(
                Message(image=image, duration=dur, cpu_cores=cores, arrival=t)
            )
        batches.append((t, msgs))
        t += batch_interval
    return Stream(batches=batches)
