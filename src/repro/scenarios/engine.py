"""Scenario runner: drive a registered scenario through the IRM simulation.

One entry point — ``run_scenario`` — replaces the hand-rolled driver loops
the benchmarks used to carry: it builds the scenario's stream(s), applies a
packing policy (any ``make_packer`` name), keeps the IRM profiler alive
across the scenario's runs (the paper's 10-run persistence), and reduces
the recorded time series to the same summary metrics the paper's figures
report (utilization, scheduled-vs-measured error, worker targets).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

import numpy as np

from ..core.binpack import make_packer
from ..core.irm import IRM
from ..core.sim import SimResult, simulate
from .registry import Scenario, get_scenario

__all__ = ["ScenarioResult", "run_scenario", "summarize_result", "POLICIES", "ACTIVE_THRESHOLD"]

# Packing policies the CLI sweeps; every name resolves via make_packer.
POLICIES = ("first-fit", "first-fit-tree", "best-fit", "worst-fit", "next-fit",
            "harmonic")

# Activity threshold shared with the seed benchmarks and the library's
# expectation checks (a worker counts as scheduled when its packed load
# exceeds 5% of capacity).
ACTIVE_THRESHOLD = 0.05


@dataclasses.dataclass
class ScenarioResult:
    """Outcome of running one scenario under one packing policy."""

    scenario: str
    policy: str
    runs: List[SimResult]
    makespans: List[float]
    summary: Dict[str, float]
    expectations: Dict[str, bool]

    @property
    def final(self) -> SimResult:
        """The last run — what the paper plots (its Figs. 8-10 use run 10)."""
        return self.runs[-1]

    @property
    def ok(self) -> bool:
        return all(self.expectations.values())


def summarize_result(res: SimResult, dt: float) -> Dict[str, float]:
    """Reduce one run's time series to the figures' summary metrics."""
    active = res.scheduled_cpu > ACTIVE_THRESHOLD
    err = res.error  # percentage points, (T, W)
    err_active = err[active]
    per_worker_load = res.scheduled_cpu.sum(axis=0) * dt  # worker-seconds
    w = len(per_worker_load)
    low = float(per_worker_load[: w // 2 + 1].sum())
    high = float(per_worker_load[w // 2 + 1:].sum())
    return {
        "completed": int(res.completed),
        "total": int(res.total),
        "makespan_s": float(res.makespan),
        "mean_scheduled_utilization_active": float(
            res.scheduled_cpu[active].mean()
        ) if active.any() else 0.0,
        "mean_busy_utilization": res.mean_busy_utilization(),
        "mean_error_pp": float(err_active.mean()) if err_active.size else 0.0,
        "mean_abs_error_pp": float(np.abs(err_active).mean())
        if err_active.size else 0.0,
        "p95_abs_error_pp": float(np.percentile(np.abs(err_active), 95))
        if err_active.size else 0.0,
        "per_worker_load_s": [float(x) for x in per_worker_load],
        "low_index_load_fraction": low / max(low + high, 1e-9),
        "max_active_workers": int(res.active_workers.max()),
        "max_target_workers": int(res.target_workers.max()),
        "peak_queue_len": int(res.queue_len.max()),
        "peak_pe_count": int(res.pe_count.max()),
    }


def run_scenario(
    scenario: Union[str, Scenario],
    *,
    policy: Optional[str] = None,
    base_seed: int = 0,
    n_runs: Optional[int] = None,
    stream_overrides: Optional[Dict[str, object]] = None,
    t_max: Optional[float] = None,
    irm: Optional[IRM] = None,
) -> ScenarioResult:
    """Run a scenario end to end and evaluate its expectations.

    ``policy`` overrides the packing algorithm inside the scenario's IRM
    config (any ``make_packer`` name); ``None`` keeps the scenario default.
    Runs ``n_runs`` back-to-back simulations with stream seeds
    ``base_seed + i``, reusing one IRM so the profiler state persists across
    runs exactly as in the paper's repeated-run experiment.  ``t_max`` and
    ``stream_overrides`` shrink or grow the experiment (smoke runs, sweeps).
    """
    scn = get_scenario(scenario) if isinstance(scenario, str) else scenario
    irm_cfg = scn.irm_config()
    if policy is not None:
        if irm is not None:
            raise ValueError(
                "policy and irm are mutually exclusive: a pre-built IRM "
                "carries its own packing configuration"
            )
        make_packer(policy)  # validate the name before mutating the config
        irm_cfg.allocator.algorithm = policy
    if irm is None:
        irm = IRM(irm_cfg)
    else:
        irm_cfg = irm.config

    sim_cfg = scn.sim_config()
    if t_max is not None:
        sim_cfg = dataclasses.replace(sim_cfg, t_max=float(t_max))

    runs: List[SimResult] = []
    makespans: List[float] = []
    n = n_runs if n_runs is not None else scn.n_runs
    overrides = stream_overrides or {}
    for i in range(n):
        stream = scn.make_stream(base_seed + i, **overrides)
        res = simulate(stream, sim_cfg, irm=irm)
        runs.append(res)
        makespans.append(float(res.makespan))

    summary = summarize_result(runs[-1], sim_cfg.dt)
    summary["makespans_s"] = makespans
    if len(makespans) > 1:
        summary["run1_vs_best_profiled"] = float(
            makespans[0] / max(min(makespans[1:]), 1e-9)
        )
    expectations = {e.name: e.evaluate(runs[-1]) for e in scn.expectations}
    return ScenarioResult(
        scenario=scn.name,
        policy=policy or irm_cfg.allocator.algorithm,
        runs=runs,
        makespans=makespans,
        summary=summary,
        expectations=expectations,
    )
