"""Scenario runner: drive a registered scenario through the IRM.

One entry point — ``run_scenario`` — replaces the hand-rolled driver loops
the benchmarks used to carry: it builds the scenario's stream(s), applies a
packing policy (any ``make_packer`` name), keeps the IRM profiler alive
across the scenario's runs (the paper's 10-run persistence), and reduces
the recorded time series to the same summary metrics the paper's figures
report (utilization, scheduled-vs-measured error, worker targets).

Three interchangeable execution backends share this runner: the
discrete-event simulator (``backend="sim"``, the default — deterministic,
tick-exact), the live asyncio runtime (``backend="live"`` — real
concurrent master/worker execution in scaled wall-clock time,
``repro.runtime``), and the same runtime over OS-process workers
(``backend="multiproc"`` — each worker is an ``mp.Process`` behind the
pickled command/data queues of ``runtime.transport.MultiprocTransport``).
All return ``SimResult``-shaped records, so the summaries, expectation
checks, and policy sweeps below are backend-blind.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from ..core.binpack import make_packer
from ..core.irm import IRM
from ..core.sim import SimResult, simulate
from ..obs import EventBus, ObsConfig, finalize_run
from .registry import Scenario, get_scenario

__all__ = ["ScenarioResult", "run_scenario", "sweep_policies",
           "summarize_result", "policies_for", "POLICIES", "VECTOR_POLICIES",
           "ACTIVE_THRESHOLD"]

# Packing policies the CLI sweeps; every name resolves via make_packer and
# supports the IRM's pre-filled open bins.  ``harmonic`` is deliberately
# absent: it has no pre-filled-bins mode (the allocator rejects it — see
# test_packing_rejects_non_anyfit) and exists for the algorithm-comparison
# microbenchmarks only.
POLICIES = ("first-fit", "first-fit-tree", "best-fit", "worst-fit", "next-fit")

# Vector policies for multi-resource scenarios (``SimConfig.resource_dims``
# beyond "cpu").  All support pre-filled vector bins; ``vector-ffd``
# reorders each packing run's drained batch largest-dominant-share first.
VECTOR_POLICIES = ("vector-first-fit", "vector-best-fit", "vector-next-fit",
                   "dominant-fit", "vector-ffd")


def policies_for(scenario: Union[str, "Scenario"]) -> Sequence[str]:
    """The policy family a scenario sweeps: vector policies when its
    cluster has more than one resource dimension, else the Any-Fit group."""
    scn = get_scenario(scenario) if isinstance(scenario, str) else scenario
    dims = getattr(scn.sim_config(), "resource_dims", ("cpu",))
    return VECTOR_POLICIES if len(dims) > 1 else POLICIES

# Activity threshold shared with the seed benchmarks and the library's
# expectation checks (a worker counts as scheduled when its packed load
# exceeds 5% of capacity).
ACTIVE_THRESHOLD = 0.05


@dataclasses.dataclass
class ScenarioResult:
    """Outcome of running one scenario under one packing policy."""

    scenario: str
    policy: str
    runs: List[SimResult]
    makespans: List[float]
    summary: Dict[str, float]
    expectations: Dict[str, bool]
    backend: str = "sim"
    # the observability bus of the *final* run (``run_scenario(obs=...)``);
    # ``None`` when observability was off
    obs: Optional[EventBus] = None

    @property
    def final(self) -> SimResult:
        """The last run — what the paper plots (its Figs. 8-10 use run 10)."""
        return self.runs[-1]

    @property
    def ok(self) -> bool:
        return all(self.expectations.values())


def summarize_result(res: SimResult, dt: float) -> Dict[str, float]:
    """Reduce one run's time series to the figures' summary metrics."""
    active = res.scheduled_cpu > ACTIVE_THRESHOLD
    err = res.error  # percentage points, (T, W)
    err_active = err[active]
    per_worker_load = res.scheduled_cpu.sum(axis=0) * dt  # worker-seconds
    w = len(per_worker_load)
    low = float(per_worker_load[: w // 2 + 1].sum())
    high = float(per_worker_load[w // 2 + 1:].sum())
    out = {
        "completed": int(res.completed),
        "total": int(res.total),
        "makespan_s": float(res.makespan),
        "mean_scheduled_utilization_active": float(
            res.scheduled_cpu[active].mean()
        ) if active.any() else 0.0,
        "mean_busy_utilization": res.mean_busy_utilization(),
        "mean_error_pp": float(err_active.mean()) if err_active.size else 0.0,
        "mean_abs_error_pp": float(np.abs(err_active).mean())
        if err_active.size else 0.0,
        "p95_abs_error_pp": float(np.percentile(np.abs(err_active), 95))
        if err_active.size else 0.0,
        "per_worker_load_s": [float(x) for x in per_worker_load],
        "low_index_load_fraction": low / max(low + high, 1e-9),
        "max_active_workers": int(res.active_workers.max()),
        "max_target_workers": int(res.target_workers.max()),
        "peak_queue_len": int(res.queue_len.max()),
        "peak_pe_count": int(res.pe_count.max()),
        "requeued": int(res.requeued),
    }
    if res.scheduled_res is not None:
        # per-dimension mean scheduled utilization over active cells
        for j, dim in enumerate(res.resource_dims):
            vals = res.scheduled_res[:, :, j][active]
            out[f"mean_scheduled_{dim}_active"] = (
                float(vals.mean()) if vals.size else 0.0
            )
        dom = res.scheduled_res.sum(axis=(0, 1)).argmax()
        out["bottleneck_dim"] = res.resource_dims[int(dom)]
    return out


def run_scenario(
    scenario: Union[str, Scenario],
    *,
    policy: Optional[str] = None,
    base_seed: int = 0,
    n_runs: Optional[int] = None,
    stream_overrides: Optional[Dict[str, object]] = None,
    t_max: Optional[float] = None,
    irm: Optional[IRM] = None,
    backend: str = "sim",
    runtime: Optional[object] = None,
    sim_overrides: Optional[Dict[str, object]] = None,
    engine: Optional[str] = None,
    obs: Optional[ObsConfig] = None,
) -> ScenarioResult:
    """Run a scenario end to end and evaluate its expectations.

    ``policy`` overrides the packing algorithm inside the scenario's IRM
    config (any ``make_packer`` name); ``None`` keeps the scenario default.
    ``engine`` overrides the allocator's packing engine (``"object"``,
    ``"numpy"``, or ``"auto"``); the numpy engine is decision-identical to
    the object packers (pinned by tests/test_packer_equivalence.py), so
    this only changes who computes the placements.
    Runs ``n_runs`` back-to-back simulations with stream seeds
    ``base_seed + i``, reusing one IRM so the profiler state persists across
    runs exactly as in the paper's repeated-run experiment.  ``t_max`` and
    ``stream_overrides`` shrink or grow the experiment (smoke runs, sweeps).
    ``sim_overrides`` replaces fields on the scenario's ``SimConfig`` —
    e.g. ``{"fail_worker_at": (0, 25.0)}`` injects a worker failure, which
    both the sim and live backends honor identically.

    ``backend`` selects the execution engine: ``"sim"`` (discrete-event,
    deterministic), ``"live"`` (the asyncio master/worker runtime; pass a
    ``repro.runtime.RuntimeConfig`` as ``runtime`` to control time scale
    and payload), or ``"multiproc"`` (the live runtime with each worker
    promoted to an OS process — ``runtime.transport`` is forced to
    ``"multiproc"`` on the runtime config).  The same IRM code schedules
    all three.

    ``obs`` (an :class:`repro.obs.ObsConfig`) enables the observability
    plane: each run records into a fresh :class:`repro.obs.EventBus` with
    an identical schema across all three backends; the *final* run's bus
    is finalized (metrics folded, transport stats merged, exported to
    ``obs.out`` when set) and returned on ``ScenarioResult.obs``.
    """
    if backend not in ("sim", "live", "multiproc"):
        raise ValueError(
            f"unknown backend {backend!r}; expected 'sim', 'live' or "
            "'multiproc' (the serving backend has its own adapter: "
            "repro.scenarios.serving.run_serving_scenario)"
        )
    if runtime is not None and backend == "sim":
        raise ValueError(
            "runtime config only applies to backend='live'/'multiproc'"
        )
    scn = get_scenario(scenario) if isinstance(scenario, str) else scenario
    if backend not in scn.backends:
        raise ValueError(
            f"scenario {scn.name!r} does not support backend {backend!r}; "
            f"supported: {scn.backends}"
        )
    irm_cfg = scn.irm_config()
    if policy is not None:
        if irm is not None:
            raise ValueError(
                "policy and irm are mutually exclusive: a pre-built IRM "
                "carries its own packing configuration"
            )
        make_packer(policy)  # validate the name before mutating the config
        irm_cfg.allocator.algorithm = policy
    if engine is not None:
        if irm is not None:
            raise ValueError(
                "engine and irm are mutually exclusive: a pre-built IRM "
                "carries its own packing configuration"
            )
        if engine not in ("object", "numpy", "auto"):
            raise ValueError(
                f"unknown engine {engine!r}; expected 'object', 'numpy' "
                "or 'auto'"
            )
        irm_cfg.allocator.engine = engine
    if irm is None:
        irm = IRM(irm_cfg)
    else:
        irm_cfg = irm.config

    sim_cfg = scn.sim_config()
    if t_max is not None:
        sim_cfg = dataclasses.replace(sim_cfg, t_max=float(t_max))
    if sim_overrides:
        sim_cfg = dataclasses.replace(sim_cfg, **sim_overrides)

    if backend in ("live", "multiproc"):
        from ..runtime.live import RuntimeConfig, run_live

        rt = runtime if runtime is not None else RuntimeConfig()
        if backend == "multiproc" and rt.transport != "multiproc":
            rt = dataclasses.replace(rt, transport="multiproc")
    runs: List[SimResult] = []
    makespans: List[float] = []
    n = n_runs if n_runs is not None else scn.n_runs
    overrides = stream_overrides or {}
    bus: Optional[EventBus] = None
    live_stats: Optional[Dict[str, object]] = None
    for i in range(n):
        stream = scn.make_stream(base_seed + i, **overrides)
        if obs is not None:
            bus = EventBus(level=obs.level)  # fresh bus per run
        if backend in ("live", "multiproc"):
            live_stats = {} if obs is not None else None
            res = run_live(stream, sim_cfg, irm=irm, runtime=rt,
                           stats=live_stats, bus=bus)
        else:
            res = simulate(stream, sim_cfg, irm=irm, bus=bus)
        runs.append(res)
        makespans.append(float(res.makespan))
    if bus is not None:
        tstats = live_stats.get("transport") if live_stats else None
        finalize_run(bus, out=obs.out, transport_stats=tstats,
                     extra={"scenario": scn.name,
                            "policy": policy or irm_cfg.allocator.algorithm,
                            "backend": backend})

    summary = summarize_result(runs[-1], sim_cfg.dt)
    summary["makespans_s"] = makespans
    if len(makespans) > 1:
        summary["run1_vs_best_profiled"] = float(
            makespans[0] / max(min(makespans[1:]), 1e-9)
        )
    expectations = {e.name: e.evaluate(runs[-1]) for e in scn.expectations}
    return ScenarioResult(
        scenario=scn.name,
        policy=policy or irm_cfg.allocator.algorithm,
        runs=runs,
        makespans=makespans,
        summary=summary,
        expectations=expectations,
        backend=backend,
        obs=bus,
    )


# ---------------------------------------------------------------------------
# Parallel policy sweeps
# ---------------------------------------------------------------------------


def _sweep_one(args: tuple) -> ScenarioResult:
    """Process-pool entry point: runs exactly one (scenario, policy) cell.

    Must be a module-level function (picklable); the scenario travels by
    *name* and is re-resolved from the registry in the child process.
    """
    name, policy, kwargs = args
    return run_scenario(name, policy=policy, **kwargs)


def sweep_policies(
    scenario: Union[str, Scenario],
    policies: Sequence[str] = POLICIES,
    *,
    jobs: Optional[int] = None,
    base_seed: int = 0,
    n_runs: Optional[int] = None,
    stream_overrides: Optional[Dict[str, object]] = None,
    t_max: Optional[float] = None,
    backend: str = "sim",
    runtime: Optional[object] = None,
    sim_overrides: Optional[Dict[str, object]] = None,
    engine: Optional[str] = None,
) -> Dict[str, ScenarioResult]:
    """Run one scenario under every policy, one process per policy.

    IRM state (profiler, queues, predictor) is constructed per policy inside
    ``run_scenario``, so the sweep cells are fully independent and the
    parallel results are identical to a serial loop — this is what makes
    broad policy evaluations (the many-cheap-runs methodology of the
    autoscaling-evaluation literature) practical on the fast sim core.

    ``jobs`` caps worker processes (default: ``min(len(policies), cpus)``);
    ``jobs=1`` — or an unregistered ad-hoc ``Scenario`` object, which cannot
    be re-resolved inside a child process — falls back to the serial loop.
    Results keep the order of ``policies``.
    """
    policies = list(policies)
    for p in policies:
        make_packer(p)  # validate every name before spawning workers
    kwargs = dict(base_seed=base_seed, n_runs=n_runs,
                  stream_overrides=stream_overrides, t_max=t_max,
                  backend=backend, runtime=runtime,
                  sim_overrides=sim_overrides, engine=engine)

    scn = get_scenario(scenario) if isinstance(scenario, str) else scenario
    try:
        registered = get_scenario(scn.name) is scn
    except KeyError:
        registered = False
    if jobs is None:
        jobs = min(len(policies), os.cpu_count() or 1)
    if jobs <= 1 or len(policies) <= 1 or not registered:
        return {p: run_scenario(scn, policy=p, **kwargs) for p in policies}

    import concurrent.futures as cf
    from concurrent.futures.process import BrokenProcessPool

    work = [(scn.name, p, kwargs) for p in policies]
    try:
        with cf.ProcessPoolExecutor(max_workers=jobs) as ex:
            results = list(ex.map(_sweep_one, work))
    except (KeyError, BrokenProcessPool):
        # Under the spawn start method (macOS/Windows) a child only sees
        # scenarios registered at import time; a dynamically registered one
        # raises KeyError there even though the parent resolved it.  Fall
        # back to the serial loop rather than crash.
        return {p: run_scenario(scn, policy=p, **kwargs) for p in policies}
    return {p: r for p, r in zip(policies, results, strict=True)}
