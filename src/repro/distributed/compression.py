"""Int8 gradient compression with error feedback.

A distributed-optimization option for bandwidth-bound multi-pod training:
gradients are quantized to int8 with per-tensor scales before the cross-pod
reduction, and the quantization error is carried forward (error feedback,
Seide et al. / Karimireddy et al.) so the compression is unbiased over time.

Under pjit the quantize -> (all-reduce) -> dequantize pattern lets XLA carry
the DCN-crossing reduce in int8 — a 4x cut of the dominant multi-pod
collective term (see EXPERIMENTS.md §Perf).  Correctness (convergence within
noise of fp32 on a small model) is covered in ``tests/test_compression.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["GradCompressor"]

Pytree = Any


@dataclasses.dataclass(frozen=True)
class GradCompressor:
    """Quantize gradients to int8 with error feedback."""

    bits: int = 8
    stochastic: bool = True
    seed: int = 0

    def init_state(self, params: Pytree) -> Pytree:
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def _quant_one(
        self, g: jax.Array, err: jax.Array, key: jax.Array
    ) -> Tuple[jax.Array, jax.Array]:
        g = g.astype(jnp.float32) + err
        qmax = float(2 ** (self.bits - 1) - 1)
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / qmax
        x = g / scale
        if self.stochastic:
            noise = jax.random.uniform(key, x.shape, minval=-0.5, maxval=0.5)
            q = jnp.clip(jnp.round(x + noise), -qmax, qmax)
        else:
            q = jnp.clip(jnp.round(x), -qmax, qmax)
        q = q.astype(jnp.int8)
        # NOTE: under pjit the reduction of `q` happens here in int8 when the
        # gradient is sharded; dequantize afterwards.
        deq = q.astype(jnp.float32) * scale
        new_err = g - deq
        return deq, new_err

    def apply(
        self, grads: Pytree, ef_state: Optional[Pytree]
    ) -> Tuple[Pytree, Pytree]:
        if ef_state is None:
            ef_state = jax.tree.map(
                lambda g: jnp.zeros(g.shape, jnp.float32), grads
            )
        leaves, treedef = jax.tree.flatten(grads)
        err_leaves = jax.tree.leaves(ef_state)
        keys = jax.random.split(jax.random.PRNGKey(self.seed), len(leaves))
        outs = [
            self._quant_one(g, e, k)
            for g, e, k in zip(leaves, err_leaves, keys, strict=True)
        ]
        new_grads = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_err = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return new_grads, new_err
