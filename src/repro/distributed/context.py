"""Activation-sharding context.

Models call ``constrain(x, axes)`` at layer boundaries with *logical* axis
names; when a mesh context is active (set by the dry-run / train / serve
drivers) this becomes ``jax.lax.with_sharding_constraint`` with the
PartitionSpec resolved through the same divisibility-aware rules as the
parameters.  Without a context it is a no-op, so model code stays
mesh-agnostic and single-device tests are untouched.

This is what pins the distributed layout: batch over the data axes,
sequence over ``model`` between blocks (Megatron-style sequence
parallelism), heads/mlp over ``model`` inside blocks.  Without these
constraints XLA's sharding propagation replicates the big activations and
re-communicates inside the attention chunk loops (measured: 1.3 TB/step/dev
on olmo-1b train_4k — see EXPERIMENTS.md §Perf iteration 1).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax

from .sharding import Rules, axes_to_pspec, make_rules

__all__ = ["activation_sharding", "constrain", "current_mesh"]

_STATE = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh, rules: Optional[Rules] = None):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = (mesh, rules or make_rules(mesh))
    try:
        yield
    finally:
        _STATE.ctx = prev


def current_mesh():
    ctx = getattr(_STATE, "ctx", None)
    return ctx[0] if ctx else None


def batch_shard_count(batch_size: int) -> int:
    """How many ways the active layout shards a batch dim of this size.

    Used by the MoE layer to pick the dispatch-group count: routing,
    sorting, and capacity-bin scatter are then *shard-local* by
    construction (a leading group axis sharded exactly like the batch), so
    the SPMD partitioner never moves dispatch state across devices.
    Returns 1 when no mesh context is active.
    """
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return 1
    mesh, rules = ctx
    spec = axes_to_pspec(("batch",), (batch_size,), rules, mesh)
    entry = spec[0]
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def constrain(x: jax.Array, axes: Tuple[Optional[str], ...]) -> jax.Array:
    """Apply a logical-axes sharding constraint if a mesh context is active."""
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(axes) != x.ndim:
        raise ValueError(f"axes {axes} rank != array rank {x.ndim}")
    spec = axes_to_pspec(axes, x.shape, rules, mesh)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec)
    )
