"""Distribution: sharding rules, gradient compression."""

from .compression import GradCompressor
from .sharding import (
    Rules,
    axes_to_pspec,
    batch_shardings,
    cache_shardings,
    make_rules,
    param_shardings,
    spec_to_pspec,
)

__all__ = [
    "GradCompressor",
    "Rules",
    "axes_to_pspec",
    "batch_shardings",
    "cache_shardings",
    "make_rules",
    "param_shardings",
    "spec_to_pspec",
]
