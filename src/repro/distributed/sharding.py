"""Logical-axis sharding rules -> NamedShardings.

Parameters and inputs carry *logical* axis names (see ``models/params.Spec``);
this module maps them onto mesh axes with divisibility- and conflict-aware
resolution:

  - an axis rule is an ordered tuple of candidate mesh axes; each candidate
    is taken greedily if (a) it is not already used by an earlier dim of the
    same tensor and (b) the accumulated shard count divides the dim size;
  - this makes one rule table serve every architecture: e.g. ``kv_heads ->
    ("model",)`` shards qwen2's 8 KV heads nowhere (8 % 16 != 0 -> replicate)
    but olmo's 16 heads 16-way; ``experts -> ("model",)`` gives qwen3-moe
    128-expert EP but falls back to expert-internal TP (via ``mlp``) for
    grok's 8 experts;
  - batch/sequence rules compose: ``kv_seq -> (data..., "model")`` gives
    decode_32k (B=128) batch-over-data + cache-seq-over-model, and
    long_500k (B=1) cache-seq over the *whole* mesh.

Training layout: FSDP over the data axes (params' ``embed`` dim) x tensor
parallelism over ``model`` (heads / mlp / vocab) — the standard 2D layout
MaxText uses; the ``pod`` axis extends FSDP/data-parallel across pods.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "Rules",
    "make_rules",
    "spec_to_pspec",
    "param_shardings",
    "batch_shardings",
    "cache_shardings",
]

Rules = Dict[str, Tuple[str, ...]]


def make_rules(mesh: Mesh, layout: str = "tp") -> Rules:
    """Two production layouts.

    ``"tp"`` (baseline, paper-faithful port of the standard 2D layout):
    batch over the data axes, tensor parallelism over ``model`` (heads /
    mlp / vocab / experts), sequence parallelism between blocks.  Costs two
    full-activation all-reduces per layer on the model axis.

    ``"fsdp"`` (beyond-paper §Perf layout): activations are batch-sharded
    over EVERY mesh axis and all compute is local; parameters stay
    2D-sharded at rest (embed dim over data axes, model dims over
    ``model``) and are all-gathered at use, ZeRO-3 style — weight
    collectives overlap with per-layer compute under the latency-hiding
    scheduler, while activation collectives disappear.  Wins whenever
    tokens-per-step is large (train_4k: 1M tokens makes weight bytes ≪
    activation bytes).
    """
    axes = tuple(mesh.axis_names)
    data_axes = tuple(a for a in axes if a != "model")  # ("pod","data") or ("data",)
    if layout == "tp":
        return {
            # parameter axes
            "vocab": ("model",),
            "embed": data_axes,            # FSDP storage of the d dim
            "mlp": ("model",),
            "heads": ("model",),
            "kv_heads": ("model",),
            "head_dim": (),
            "experts": ("model",),
            "layers": (),
            # activation / input axes
            "batch": data_axes,
            "batch_data": data_axes,       # batch over data ONLY (CE chunks:
                                           # leaves "model" free for vocab)
            "seq": ("model",),             # sequence parallelism
            "kv_seq": data_axes + ("model",),
            "pages": data_axes + ("model",),
        }
    if layout == "fsdp":
        return {
            # parameter axes: same 2D-sharded storage as "tp" …
            "vocab": ("model",),
            "embed": data_axes,
            "mlp": ("model",),
            "heads": ("model",),
            "kv_heads": ("model",),
            "head_dim": (),
            "experts": ("model",),
            "layers": (),
            # … but activations shard batch over EVERYTHING and nothing else
            "batch": data_axes + ("model",),
            "batch_data": data_axes,
            "seq": (),
            "kv_seq": data_axes + ("model",),
            "pages": data_axes + ("model",),
        }
    if layout == "serve":
        # decode-optimized: weights REPLICATED over the data axes (read
        # from HBM at 819 GB/s instead of re-gathered over 50 GB/s ICI
        # every token), TP over "model" only; KV cache batch-over-data +
        # sequence-over-model with the shard_map flash-decode combine.
        return {
            "vocab": ("model",),
            "embed": (),
            "mlp": ("model",),
            "heads": ("model",),
            "kv_heads": ("model",),
            "head_dim": (),
            "experts": ("model",),
            "layers": (),
            "batch": data_axes,
            "batch_data": data_axes,
            "seq": ("model",),
            "kv_seq": data_axes + ("model",),
            "pages": data_axes + ("model",),
        }
    raise ValueError(f"unknown layout {layout!r}")


def _resolve_dim(
    name: Optional[str],
    size: int,
    rules: Rules,
    mesh: Mesh,
    used: set,
) -> Any:
    if name is None:
        return None
    candidates = rules.get(name, ())
    chosen = []
    prod = 1
    for ax in candidates:
        ax_size = mesh.shape[ax]
        if ax in used:
            continue
        if size % (prod * ax_size) != 0:
            continue
        chosen.append(ax)
        prod *= ax_size
    for ax in chosen:
        used.add(ax)
    if not chosen:
        return None
    return tuple(chosen) if len(chosen) > 1 else chosen[0]


def axes_to_pspec(
    axes: Tuple[Optional[str], ...],
    shape: Tuple[int, ...],
    rules: Rules,
    mesh: Mesh,
) -> P:
    used: set = set()
    entries = [
        _resolve_dim(name, size, rules, mesh, used)
        for name, size in zip(axes, shape, strict=True)
    ]
    return P(*entries)


def _is_spec(x: Any) -> bool:
    # duck-typed to avoid importing models.params (circular import)
    return hasattr(x, "axes") and hasattr(x, "shape") and hasattr(x, "init")


def spec_to_pspec(spec: Any, rules: Rules, mesh: Mesh) -> P:
    return axes_to_pspec(spec.axes, spec.shape, rules, mesh)


def param_shardings(specs: Any, mesh: Mesh, rules: Optional[Rules] = None) -> Any:
    rules = rules or make_rules(mesh)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_to_pspec(s, rules, mesh)),
        specs,
        is_leaf=_is_spec,
    )


# ---------------------------------------------------------------------------
# Input batches and caches (ShapeDtypeStructs or arrays)
# ---------------------------------------------------------------------------

_BATCH_AXES = {
    # training / prefill inputs
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "segment_ids": ("batch", "seq"),
    "positions": ("batch", "seq"),
    "vision_embeds": ("batch", None, "embed"),
    "enc_embeds": ("batch", "seq", "embed"),
    "enc_segment_ids": ("batch", "seq"),
}


def batch_shardings(
    batch: Any, mesh: Mesh, rules: Optional[Rules] = None, *, decode: bool = False
) -> Any:
    """Shardings for a batch dict (by key), ShapeDtypeStruct-driven."""
    rules = rules or make_rules(mesh)
    out = {}
    for key, leaf in batch.items():
        if decode and key == "tokens":
            axes: Tuple[Optional[str], ...] = ("batch", None)
        else:
            axes = _BATCH_AXES.get(key, ("batch",) + (None,) * (len(leaf.shape) - 1))
        out[key] = NamedSharding(mesh, axes_to_pspec(axes, leaf.shape, rules, mesh))
    return out


def _cache_leaf_axes(path: Tuple[str, ...], shape: Tuple[int, ...]) -> Tuple:
    """Logical axes for a cache leaf, keyed by its path/rank.

    Dense KV caches are (layers, B, S, KVH, hd): batch over data, cache
    sequence over whatever remains (incl. the whole mesh for B=1).
    Recurrent states (mamba/xlstm) are small: shard batch + inner dim.
    """
    name = path[-1] if path else ""
    if name in ("k", "v", "ck", "cv") and len(shape) == 5:
        return ("layers", "batch", "kv_seq", "kv_heads", None)
    if name == "len":
        return ("batch",)
    if name == "enc_segment_ids":
        return ("batch", None)
    if name == "conv":  # (layers, B, k-1, di)
        return ("layers", "batch", None, "mlp")
    if name == "ssm":  # (layers, B, di, ds)
        return ("layers", "batch", "mlp", None)
    if name == "C" and len(shape) == 5:  # (layers, B, H, dh, dh)
        return ("layers", "batch", "heads", None, None)
    if name in ("n", "m", "c", "h"):
        return ("layers", "batch", "heads") + (None,) * (len(shape) - 3)
    # fallback: batch on dim 1 if rank >= 2 (layers-stacked), else replicate
    if len(shape) >= 2:
        return ("layers", "batch") + (None,) * (len(shape) - 2)
    return (None,) * len(shape)


def cache_shardings(cache: Any, mesh: Mesh, rules: Optional[Rules] = None) -> Any:
    rules = rules or make_rules(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, leaf in flat:
        names = tuple(
            getattr(k, "key", getattr(k, "name", str(k))) for k in path
        )
        axes = _cache_leaf_axes(names, leaf.shape)
        out.append(
            NamedSharding(mesh, axes_to_pspec(axes, leaf.shape, rules, mesh))
        )
    return jax.tree_util.tree_unflatten(treedef, out)
