"""Checkpoint save/restore with elastic resharding.

Design (single-controller; per-host sharded IO on a real pod):
  - a checkpoint is a directory ``step_<N>/`` holding one ``.npy`` per
    parameter leaf (path-encoded filename) plus ``meta.json`` (tree
    structure, shapes, dtypes, step, content hashes),
  - writes go to ``step_<N>.tmp/`` and are atomically renamed — a crash
    mid-save never corrupts the latest checkpoint (fault tolerance),
  - ``save_async`` snapshots arrays to host memory synchronously (cheap)
    and writes in a background thread (overlaps the next training steps),
  - restore is *elastic*: arrays are ``device_put`` against the shardings
    derived from the *current* mesh — restoring a 512-chip checkpoint onto
    a different topology (or 1 CPU device) just works, which is the
    checkpoint/restart + elastic-scaling story for node failures.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]

Pytree = Any
_SEP = "__"


def _flatten(tree: Pytree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = _SEP.join(
            str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
            for k in path
        )
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ---- save ----------------------------------------------------------------
    def save(self, step: int, tree: Pytree, *, blocking: bool = True) -> str:
        """Snapshot to host, then write (optionally in the background)."""
        host = [(name, np.asarray(leaf)) for name, leaf in _flatten(tree)]
        treedef = jax.tree_util.tree_structure(tree)
        if blocking:
            return self._write(step, host, treedef)
        self.wait()  # one outstanding async save at a time
        self._thread = threading.Thread(
            target=self._write, args=(step, host, treedef), daemon=True
        )
        self._thread.start()
        return self._path(step)

    def save_async(self, step: int, tree: Pytree) -> str:
        return self.save(step, tree, blocking=False)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def _write(self, step: int, host: List[Tuple[str, np.ndarray]], treedef) -> str:
        final = self._path(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        meta: Dict[str, Any] = {"step": step, "leaves": []}
        for name, arr in host:
            fname = f"{name}.npy"
            np.save(os.path.join(tmp, fname), arr)
            meta["leaves"].append(
                {
                    "name": name,
                    "file": fname,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sha256": hashlib.sha256(arr.tobytes()).hexdigest()[:16],
                }
            )
        meta["treedef"] = str(treedef)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._path(s), ignore_errors=True)

    # ---- restore ---------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: int,
        target: Pytree,
        shardings: Optional[Pytree] = None,
        *,
        verify: bool = True,
    ) -> Pytree:
        """Restore into the structure of ``target`` (arrays or SDS).

        ``shardings`` (same structure) enables elastic restore onto the
        current mesh; without it arrays land on the default device.
        """
        path = self._path(step)
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        by_name = {leaf["name"]: leaf for leaf in meta["leaves"]}

        names = [name for name, _ in _flatten(target)]
        flat_target, treedef = jax.tree_util.tree_flatten(target)
        flat_shard = (
            jax.tree_util.tree_flatten(shardings)[0]
            if shardings is not None
            else [None] * len(flat_target)
        )
        out = []
        for name, tgt, shd in zip(names, flat_target, flat_shard, strict=True):
            info = by_name.get(name)
            if info is None:
                raise KeyError(f"checkpoint {path} is missing leaf {name!r}")
            arr = np.load(os.path.join(path, info["file"]))
            if verify:
                digest = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
                if digest != info["sha256"]:
                    raise IOError(f"checksum mismatch for {name} in {path}")
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(
                    f"shape mismatch for {name}: ckpt {arr.shape} vs "
                    f"target {tgt.shape}"
                )
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.numpy.asarray(arr, dtype=tgt.dtype))
        return jax.tree_util.tree_unflatten(treedef, out)
