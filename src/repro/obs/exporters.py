"""Exporters: JSONL event log, Prometheus text exposition, run summary.

These are the only obs modules that touch the filesystem, and they are
called exclusively from synchronous engine/CLI code after a run has
drained — never from the event loop or a worker (R1 keeps it that way).
"""

from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Iterable, List, Optional

from .metrics import MetricsRegistry

RUN_SUMMARY_SCHEMA = 1

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def write_jsonl(path, events: Iterable[dict]) -> None:
    """One event per line, key order preserved from the envelope."""
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    with open(p, "w", encoding="utf-8") as f:
        for e in events:
            f.write(json.dumps(e) + "\n")


def load_events(path) -> List[dict]:
    out = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def _prom_name(name: str) -> str:
    return _NAME_RE.sub("_", name)


def prometheus_text(registry: MetricsRegistry) -> str:
    """Prometheus text exposition (name-sorted, deterministic)."""
    lines = []
    for name, snap in registry.snapshot().items():
        pname = _prom_name(name)
        kind = snap["type"]
        lines.append(f"# TYPE {pname} {kind}")
        if kind in ("counter", "gauge"):
            lines.append(f"{pname} {snap['value']}")
        else:  # histogram
            cum = 0
            for bound, c in zip(snap["bounds"], snap["counts"][:-1],
                                strict=True):
                cum += c
                lines.append(f'{pname}_bucket{{le="{bound}"}} {cum}')
            cum += snap["counts"][-1]
            lines.append(f'{pname}_bucket{{le="+Inf"}} {cum}')
            lines.append(f"{pname}_sum {snap['sum']}")
            lines.append(f"{pname}_count {snap['count']}")
    return "\n".join(lines) + "\n"


def run_summary(registry: MetricsRegistry,
                extra: Optional[dict] = None) -> dict:
    out = {"schema": RUN_SUMMARY_SCHEMA, "metrics": registry.snapshot()}
    if extra:
        out.update(extra)
    return out


def write_run_summary(path, registry: MetricsRegistry,
                      extra: Optional[dict] = None) -> None:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(run_summary(registry, extra), indent=2) + "\n",
                 encoding="utf-8")


#: ``Transport.stats()`` keys promoted to first-class run-summary metrics
#: (they previously died inside the transport unless a caller dug).
TRANSPORT_METRIC_KEYS = (
    "profiler_drift_pp",
    "ser_bytes_per_msg",
    "ser_ms_per_msg",
    "serialize_ms",
    "data_bytes_out",
    "data_bytes_in",
    "data_msgs_out",
    "data_msgs_in",
    "workers_spawned",
)


def fold_transport_stats(registry: MetricsRegistry, stats: dict) -> None:
    """Surface the transport's counters as ``transport.*`` gauges."""
    for key in TRANSPORT_METRIC_KEYS:
        v = stats.get(key)
        if isinstance(v, (int, float)):
            registry.gauge(f"transport.{key}").set(float(v))


def finalize_run(bus, *, out=None, transport_stats: Optional[dict] = None,
                 extra: Optional[dict] = None) -> None:
    """Post-run folding + optional export.

    Derives master-side metrics from the event log, merges the transport
    counters, drops the bus's clock closure (so results survive the
    sweep pool's pickling), and — when ``out`` is set — writes
    ``events.jsonl``, ``metrics.prom``, and ``summary.json`` into it.
    """
    from .analyze import fold_events  # local import: avoid cycle

    fold_events(bus.registry, bus.events)
    if transport_stats:
        fold_transport_stats(bus.registry, transport_stats)
    bus.now = None
    if out is not None:
        d = Path(out)
        d.mkdir(parents=True, exist_ok=True)
        write_jsonl(d / "events.jsonl", bus.events)
        (d / "metrics.prom").write_text(prometheus_text(bus.registry),
                                        encoding="utf-8")
        write_run_summary(d / "summary.json", bus.registry, extra=extra)
