"""IRM decision audit: why each request landed in its bin.

The allocator captures, per packing run (flag-gated, pure reads — the
decision path is untouched), the policy, the per-bin free vector *before*
the run, and each item's size and assignment.  This module replays the
policy's semantics over that snapshot to derive the rejection reason for
every bin scanned before the winner — "why did first-fit skip bin 3" —
and emits the whole record as one ``irm.pack`` event.

The replay is a post-hoc explanation, not a second decision: free
capacity is decremented by the recorded assignments, so the explanation
is consistent with what the packer actually did even if the replay's
notion of "fits" drifted (it uses the same ``free + eps >= size`` test
the packers do).
"""

from __future__ import annotations

from typing import List, Optional, Sequence

_EPS = 1e-9
#: Per-item cap on recorded rejections (keeps event size bounded at
#: fleet scale; the paper-scale scenarios never hit it).
MAX_REJECTIONS = 32


def _family(policy: str) -> str:
    if "best" in policy:
        return "best"
    if "worst" in policy:
        return "worst"
    if "next" in policy:
        return "next"
    if "first" in policy:
        return "first"
    return "other"


def _insufficient(free_row: Sequence[float], size: Sequence[float],
                  dims: Optional[Sequence[str]]) -> str:
    for d, (f, s) in enumerate(zip(free_row, size)):
        if f + _EPS < s:
            name = dims[d] if dims and d < len(dims) else f"dim{d}"
            return f"insufficient {name}: need {s:.4g}, free {f:.4g}"
    return "insufficient capacity"


def explain_rejections(
    policy: str,
    capacity: Sequence[float],
    free_before: Sequence[Sequence[float]],
    sizes: Sequence[Sequence[float]],
    assignments: Sequence[int],
    dims: Optional[Sequence[str]] = None,
) -> List[List[dict]]:
    """Per item, the bins rejected before its winning bin and why.

    ``free_before`` is the per-bin free vector at the start of the run;
    the replay opens new bins at full ``capacity`` as assignments demand
    and decrements free capacity item by item.
    """
    free: List[List[float]] = [list(map(float, row)) for row in free_before]
    cap = list(map(float, capacity))
    cursor = 0  # next-fit scan position
    out: List[List[dict]] = []
    for size, b in zip(sizes, assignments):
        b = int(b)
        size = list(map(float, size))
        while b >= len(free):
            free.append(list(cap))

        def fits(j: int) -> bool:
            return all(f + _EPS >= s for f, s in zip(free[j], size))

        fam = _family(policy)
        rej: List[dict] = []
        if fam == "first":
            scanned = range(b)
        elif fam in ("best", "worst"):
            scanned = [j for j in range(len(free)) if j != b]
        elif fam == "next":
            scanned = range(b)
        else:
            scanned = range(b)
        for j in scanned:
            if len(rej) >= MAX_REJECTIONS:
                rej.append({"bin": -1, "reason": "... (truncated)"})
                break
            if fam == "next" and j < cursor:
                reason = "behind the next-fit cursor"
            elif not fits(j):
                reason = _insufficient(free[j], size, dims)
            elif fam == "best":
                reason = f"fits, but looser residual than bin {b}"
            elif fam == "worst":
                reason = f"fits, but less free capacity than bin {b}"
            elif fam == "first":
                # first-fit never skips a fitting bin; if we get here the
                # replay's eps disagrees with the packer's — say so.
                reason = "fits in replay (eps boundary); packer rejected"
            else:
                reason = f"scored lower than bin {b} under {policy}"
            rej.append({"bin": j, "reason": reason})
        if fam == "next":
            cursor = b
        out.append(rej)
        for d in range(len(size)):
            free[b][d] -= size[d]
    return out


def emit_packing_audit(bus, policy: str, packing) -> None:
    """Emit one ``irm.pack`` event for a completed packing run.

    The single emit site for this event type, shared by the sim and live
    drivers.  No-op unless the bus is present, at level ``full``, and the
    step actually ran a packing.  Works with or without allocator audit
    capture (placements/free_before are empty without it).
    """
    if bus is None or packing is None or not bus.audit:
        return
    a = packing.audit
    placements: List[dict] = []
    free_before: List[List[float]] = []
    pol = policy
    if a is not None:
        pol = a["policy"]
        free_before = [[float(x) for x in row] for row in a["free_before"]]
        rejections = explain_rejections(
            a["policy"], a["capacity"], a["free_before"], a["sizes"],
            a["assignments"], dims=a.get("dims"),
        )
        for i, b in enumerate(a["assignments"]):
            placements.append({
                "req_id": a["req_ids"][i],
                "image": a["images"][i],
                "size": [float(s) for s in a["sizes"][i]],
                "bin": int(b),
                "rejections": rejections[i],
            })
    bus.emit(
        "irm.pack",
        policy=pol,
        requests=len(packing.placements),
        num_bins=int(packing.num_bins),
        target_workers=int(packing.target_workers),
        ideal_bins=int(packing.ideal_bins),
        placements=placements,
        free_before=free_before,
    )
