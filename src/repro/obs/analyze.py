"""Event-log analysis: spans, latency decomposition, audit & drift reports.

Everything here consumes a plain list of event dicts (in-memory from an
:class:`~repro.obs.bus.EventBus` or loaded from a JSONL log) — the
analyzer never needs the run that produced them, which is what makes
"one command instead of printf archaeology" work on CI artifacts.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from .bus import ENVELOPE_FIELDS

MANIFEST_PATH = Path(__file__).parent / "event_manifest.json"


def load_manifest(path=None) -> dict:
    return json.loads(Path(path or MANIFEST_PATH).read_text(encoding="utf-8"))


# ---------------------------------------------------------------------------
# Span reconstruction + latency decomposition
# ---------------------------------------------------------------------------


def spans(events: List[dict]) -> Dict[int, List[dict]]:
    """Per-message event lists (``msg.*`` only), in emission order."""
    by_msg: Dict[int, List[dict]] = {}
    for e in events:
        if e["ev"].startswith("msg."):
            by_msg.setdefault(e["msg_id"], []).append(e)
    return by_msg


def latency_decomposition(events: List[dict]) -> dict:
    """Decompose every completed message's e2e latency into components.

    Per message (times in scenario seconds):

    - ``queue_wait`` — first ``msg.enqueued`` to the *last* ``msg.pulled``
      (requeued messages charge their abandoned attempts to the queue)
    - ``handoff``    — last pull to the authoritative ``start_t`` stamped
      on the completion (transport/scheduling cost of starting work)
    - ``service``    — ``start_t`` to ``done_t``
    - ``e2e``        — the sum of the three, and identically
      ``done_t - enqueued.t`` up to float re-association
    - ``e2e_arrival``— ``done_t - arrival`` (the stream's nominal arrival
      time; the exact quantity BENCH_runtime.json's pipeline reports)
    """
    per_message: List[dict] = []
    by_msg = spans(events)
    for msg_id in sorted(by_msg):
        evs = by_msg[msg_id]
        enq = next((e for e in evs if e["ev"] == "msg.enqueued"), None)
        done = next((e for e in reversed(evs) if e["ev"] == "msg.completed"),
                    None)
        if enq is None or done is None:
            continue
        pulls = [e for e in evs if e["ev"] == "msg.pulled"]
        last_pull_t = pulls[-1]["t"] if pulls else done["start_t"]
        queue_wait = last_pull_t - enq["t"]
        handoff = done["start_t"] - last_pull_t
        service = done["done_t"] - done["start_t"]
        per_message.append({
            "msg_id": msg_id,
            "image": done["image"],
            "attempts": len(pulls),
            "queue_wait": queue_wait,
            "handoff": handoff,
            "service": service,
            "e2e": queue_wait + handoff + service,
            "e2e_arrival": done["done_t"] - done["arrival"],
        })
    by_image: Dict[str, dict] = {}
    for row in per_message:
        agg = by_image.setdefault(row["image"], {
            "count": 0, "queue_wait": 0.0, "handoff": 0.0,
            "service": 0.0, "e2e": 0.0,
        })
        agg["count"] += 1
        for k in ("queue_wait", "handoff", "service", "e2e"):
            agg[k] += row[k]
    for agg in by_image.values():
        n = agg["count"]
        for k in ("queue_wait", "handoff", "service", "e2e"):
            agg[k] = agg[k] / n if n else 0.0
    totals = {"count": len(per_message)}
    for k in ("queue_wait", "handoff", "service", "e2e"):
        vals = [r[k] for r in per_message]
        totals[k] = sum(vals) / len(vals) if vals else 0.0
    return {"per_message": per_message, "by_image": by_image,
            "totals": totals}


def e2e_percentiles(events: List[dict]) -> dict:
    """p50/p95/p99 of ``done_t - arrival`` over completed messages —
    computed exactly as ``benchmarks/runtime_throughput.py`` computes the
    pipeline's latency percentiles, so the analyzer reproduces
    ``BENCH_runtime.json`` from the event log alone."""
    lat = [e["done_t"] - e["arrival"] for e in events
           if e["ev"] == "msg.completed"]
    if not lat:
        return {"count": 0, "p50": None, "p95": None, "p99": None}
    arr = np.asarray(lat, dtype=np.float64)
    return {
        "count": len(lat),
        "p50": float(np.percentile(arr, 50)),
        "p95": float(np.percentile(arr, 95)),
        "p99": float(np.percentile(arr, 99)),
    }


def critical_path(events: List[dict], msg_id: int) -> List[dict]:
    """The ordered event chain of one message, with per-hop deltas."""
    evs = spans(events).get(msg_id, [])
    out = []
    prev_t: Optional[float] = None
    for e in evs:
        out.append({
            "ev": e["ev"],
            "t": e["t"],
            "dt": 0.0 if prev_t is None else e["t"] - prev_t,
            "worker": e.get("worker"),
            "pe": e.get("pe"),
        })
        prev_t = e["t"]
    return out


# ---------------------------------------------------------------------------
# Metric folding (master-side derivation from the event log)
# ---------------------------------------------------------------------------


def fold_events(registry, events: List[dict]) -> None:
    """Derive the master's counters/histograms from the event log."""
    for e in events:
        registry.counter("events." + e["ev"]).inc()
    rows = latency_decomposition(events)["per_message"]
    for r in rows:
        registry.histogram("latency.e2e_s").observe(r["e2e_arrival"])
        registry.histogram("latency.queue_wait_s").observe(r["queue_wait"])
        registry.histogram("latency.service_s").observe(r["service"])
        if r["attempts"] > 1:
            registry.counter("msgs.reexecuted").inc()


# ---------------------------------------------------------------------------
# Schema: observation, validation, cross-log drift
# ---------------------------------------------------------------------------


def schema_of(events: List[dict]) -> Dict[str, List[str]]:
    """Observed payload field set per event type (sorted, envelope
    excluded).  ``json.dumps(schema_of(...), sort_keys=True)`` is the
    byte-identity the cross-backend test pins."""
    sch: Dict[str, set] = {}
    for e in events:
        fields = set(e) - set(ENVELOPE_FIELDS)
        sch.setdefault(e["ev"], set()).update(fields)
    return {ev: sorted(fields) for ev, fields in sorted(sch.items())}


def validate_events(events: List[dict],
                    manifest: Optional[dict] = None) -> List[str]:
    """Violations of the committed manifest: unknown types, payload field
    sets that differ from the pinned schema.  Empty list == clean."""
    man = (manifest or load_manifest())["events"]
    violations: List[str] = []
    seen: set = set()
    for e in events:
        ev = e["ev"]
        fields = tuple(sorted(set(e) - set(ENVELOPE_FIELDS)))
        key = (ev, fields)
        if key in seen:
            continue
        seen.add(key)
        if ev not in man:
            violations.append(f"event type {ev!r} not in event_manifest.json")
            continue
        pinned = tuple(sorted(man[ev]))
        if fields != pinned:
            violations.append(
                f"{ev}: payload fields {list(fields)} != manifest "
                f"{list(pinned)}"
            )
    return violations


def _counts_by_type(events: List[dict]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for e in events:
        out[e["ev"]] = out.get(e["ev"], 0) + 1
    return out


def drift_report(events_a: List[dict], events_b: List[dict]) -> dict:
    """Structural diff of two event logs (e.g. sim vs live on the same
    scenario): schema drift, per-type count deltas, latency-component
    drift, and requeue/kill accounting."""
    sa, sb = schema_of(events_a), schema_of(events_b)
    only_a = sorted(set(sa) - set(sb))
    only_b = sorted(set(sb) - set(sa))
    field_diffs = {
        ev: {"a": sa[ev], "b": sb[ev]}
        for ev in sorted(set(sa) & set(sb)) if sa[ev] != sb[ev]
    }
    ca, cb = _counts_by_type(events_a), _counts_by_type(events_b)
    counts = {ev: {"a": ca.get(ev, 0), "b": cb.get(ev, 0)}
              for ev in sorted(set(ca) | set(cb))}
    la = latency_decomposition(events_a)["totals"]
    lb = latency_decomposition(events_b)["totals"]
    latency = {
        "a": la, "b": lb,
        "delta": {k: lb[k] - la[k]
                  for k in ("queue_wait", "handoff", "service", "e2e")},
    }
    return {
        "schema": {"only_in_a": only_a, "only_in_b": only_b,
                   "field_diffs": field_diffs},
        "counts": counts,
        "latency": latency,
    }


def render_drift(report: dict) -> str:
    lines = ["drift report (a vs b):"]
    sch = report["schema"]
    if sch["only_in_a"] or sch["only_in_b"] or sch["field_diffs"]:
        lines.append(f"  schema: only_in_a={sch['only_in_a']} "
                     f"only_in_b={sch['only_in_b']}")
        for ev, d in sch["field_diffs"].items():
            lines.append(f"  schema {ev}: a={d['a']} b={d['b']}")
    else:
        lines.append("  schema: identical")
    lines.append("  event counts (a / b):")
    for ev, c in report["counts"].items():
        marker = "" if c["a"] == c["b"] else "   <-- differs"
        lines.append(f"    {ev:<18} {c['a']:>6} / {c['b']:<6}{marker}")
    lat = report["latency"]
    lines.append("  mean latency components (a -> b, delta):")
    for k in ("queue_wait", "handoff", "service", "e2e"):
        lines.append(
            f"    {k:<11} {lat['a'][k]:>9.3f} -> {lat['b'][k]:<9.3f} "
            f"({lat['delta'][k]:+.3f})"
        )
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Decision-audit rendering
# ---------------------------------------------------------------------------


def audit_report(events: List[dict], run: Optional[int] = None) -> str:
    """Human-readable render of the IRM decision audit."""
    packs = [e for e in events if e["ev"] == "irm.pack"]
    if run is not None:
        packs = packs[run:run + 1]
    if not packs:
        return ("no irm.pack events in this log (obs level 'lifecycle' "
                "drops them; rerun with --obs-level full)")
    lines = []
    for i, p in enumerate(packs):
        lines.append(
            f"packing run {i} [t={p['t']:.2f} tick={p['tick']:.2f}] "
            f"policy={p['policy']} requests={p['requests']} "
            f"bins={p['num_bins']} target={p['target_workers']} "
            f"ideal={p['ideal_bins']}"
        )
        if p["free_before"]:
            free = ", ".join(
                f"bin {j}: [{', '.join(f'{x:.3f}' for x in row)}]"
                for j, row in enumerate(p["free_before"])
            )
            lines.append(f"  free before: {free}")
        for pl in p["placements"]:
            size = ", ".join(f"{s:.3g}" for s in pl["size"])
            lines.append(
                f"  req {pl['req_id']} ({pl['image']}, size [{size}]) "
                f"-> bin {pl['bin']}"
            )
            for rej in pl["rejections"]:
                lines.append(f"      bin {rej['bin']}: {rej['reason']}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Summary
# ---------------------------------------------------------------------------


def summarize(events: List[dict]) -> dict:
    counts = _counts_by_type(events)
    workers = {e["worker"] for e in events if "worker" in e}
    tmax = max((e["t"] for e in events), default=0.0)
    return {
        "events": len(events),
        "counts": counts,
        "distinct_workers": len(workers),
        "t_last": tmax,
        "e2e": e2e_percentiles(events),
    }
