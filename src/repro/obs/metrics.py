"""Mergeable metric instruments: counters, gauges, fixed-bucket histograms.

The registry is the unit of aggregation for the observability plane.  Every
instrument is a *mergeable delta*: a worker-side registry accumulates
observations locally, ``delta()`` snapshots what changed since the last
flush (resetting the baseline), and the delta — plain dicts of floats and
lists, nothing custom — rides the existing pickled data queue to the
master, whose registry ``merge()``s it.  The same mechanism works over any
transport that can move JSON-shaped payloads (the planned socket transport
included), which is why the wire format here is primitives only and never
the instrument objects themselves.

Merge semantics per instrument:

- **Counter** — deltas add.  Merging N worker deltas in any order yields
  the same total (float addition over non-negative increments).
- **Gauge** — last write wins; a delta carries the gauge only when it
  changed since the flush.
- **Histogram** — fixed bucket bounds chosen at creation; deltas are
  per-bucket count differences plus (sum, count) differences, merged by
  elementwise addition.  Merging rejects mismatched bounds rather than
  resampling.
"""

from __future__ import annotations

import bisect
from typing import Dict, List, Optional, Sequence

# Buckets in *scenario seconds* — wide enough for e2e latency on the
# paper's scenarios and for sub-second service/handoff components.
DEFAULT_BOUNDS = (0.1, 0.25, 0.5, 1.0, 2.0, 5.0, 10.0, 30.0, 60.0,
                  120.0, 300.0, 600.0)


class Counter:
    """Monotone float counter."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket histogram: ``len(bounds) + 1`` counts (last = +Inf)."""

    __slots__ = ("bounds", "counts", "total", "count")
    kind = "histogram"

    def __init__(self, bounds: Sequence[float] = DEFAULT_BOUNDS) -> None:
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError("histogram bounds must be sorted ascending")
        self.counts = [0] * (len(self.bounds) + 1)
        self.total = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.total += v
        self.count += 1

    def snapshot(self) -> dict:
        return {
            "type": "histogram",
            "bounds": list(self.bounds),
            "counts": list(self.counts),
            "sum": self.total,
            "count": self.count,
        }


class MetricsRegistry:
    """A named collection of instruments with delta/merge aggregation."""

    def __init__(self) -> None:
        self._metrics: Dict[str, object] = {}
        self._flushed: Dict[str, dict] = {}

    # -- instrument accessors (create on first use, type-checked after) --

    def _get(self, name: str, cls, *args):
        inst = self._metrics.get(name)
        if inst is None:
            inst = cls(*args)
            self._metrics[name] = inst
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} is {type(inst).__name__}, "
                f"not {cls.__name__}"
            )
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  bounds: Sequence[float] = DEFAULT_BOUNDS) -> Histogram:
        return self._get(name, Histogram, bounds)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    # -- aggregation ----------------------------------------------------

    def snapshot(self) -> dict:
        """Full current state, name-sorted, primitives only."""
        return {n: self._metrics[n].snapshot() for n in sorted(self._metrics)}

    def delta(self) -> dict:
        """What changed since the previous ``delta()``; resets the
        baseline.  Returns primitives only — safe to pickle/json."""
        out = {}
        for name in sorted(self._metrics):
            snap = self._metrics[name].snapshot()
            base = self._flushed.get(name)
            d = _subtract(snap, base)
            if d is not None:
                out[name] = d
            self._flushed[name] = snap
        return out

    def merge(self, delta: Optional[dict]) -> None:
        """Fold a ``delta()`` (or full ``snapshot()``) from another
        registry into this one."""
        if not delta:
            return
        for name, payload in delta.items():
            kind = payload["type"]
            if kind == "counter":
                self.counter(name).inc(payload["value"])
            elif kind == "gauge":
                self.gauge(name).set(payload["value"])
            elif kind == "histogram":
                h = self.histogram(name, payload["bounds"])
                if list(h.bounds) != list(payload["bounds"]):
                    raise ValueError(
                        f"histogram {name!r}: bounds mismatch on merge"
                    )
                for i, c in enumerate(payload["counts"]):
                    h.counts[i] += c
                h.total += payload["sum"]
                h.count += payload["count"]
            else:
                raise ValueError(f"unknown instrument type {kind!r}")


def _subtract(snap: dict, base: Optional[dict]) -> Optional[dict]:
    """Delta between two snapshots of the same instrument; None = no
    change worth shipping."""
    kind = snap["type"]
    if kind == "counter":
        prev = base["value"] if base else 0.0
        d = snap["value"] - prev
        if d == 0.0:
            return None
        return {"type": "counter", "value": d}
    if kind == "gauge":
        if base is not None and base["value"] == snap["value"]:
            return None
        return dict(snap)
    if kind == "histogram":
        if base is None:
            if snap["count"] == 0:
                return None
            return dict(snap)
        if snap["count"] == base["count"]:
            return None
        return {
            "type": "histogram",
            "bounds": list(snap["bounds"]),
            "counts": [a - b for a, b in zip(snap["counts"], base["counts"],
                                             strict=True)],
            "sum": snap["sum"] - base["sum"],
            "count": snap["count"] - base["count"],
        }
    raise ValueError(f"unknown instrument type {kind!r}")
