"""Unified observability plane: events, metrics, exporters, analyzer.

One schema across all three backends (sim, live in-process, multiproc):

- :class:`EventBus` — typed event sink (message spans, worker/PE
  lifecycle, IRM decision audit), stamped in both nominal-tick and
  backend time.  Drivers thread it behind ``if bus is not None`` guards.
- :class:`MetricsRegistry` — counters/gauges/fixed-bucket histograms as
  *mergeable deltas*; multiproc workers flush deltas over the existing
  data queue and the master folds them into one view.
- Exporters — JSONL event log, Prometheus text exposition, run-summary
  JSON (``finalize_run`` writes all three).
- Analyzer — ``python -m repro.obs``: latency decomposition, per-message
  critical paths, the "why did first-fit skip bin 3" audit render, and
  event-log drift reports.

Entry point for callers: ``run_scenario(..., obs=ObsConfig(...))`` or
the CLI's ``--obs-out DIR --obs-level {lifecycle,full}``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from .analyze import (
    audit_report,
    drift_report,
    e2e_percentiles,
    fold_events,
    latency_decomposition,
    load_manifest,
    render_drift,
    schema_of,
    summarize,
    validate_events,
)
from .audit import emit_packing_audit, explain_rejections
from .bus import ENVELOPE_FIELDS, EventBus
from .exporters import (
    finalize_run,
    fold_transport_stats,
    load_events,
    prometheus_text,
    run_summary,
    write_jsonl,
    write_run_summary,
)
from .metrics import Counter, Gauge, Histogram, MetricsRegistry

__all__ = [
    "ObsConfig",
    "EventBus",
    "ENVELOPE_FIELDS",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "emit_packing_audit",
    "explain_rejections",
    "finalize_run",
    "fold_events",
    "fold_transport_stats",
    "write_jsonl",
    "load_events",
    "prometheus_text",
    "run_summary",
    "write_run_summary",
    "latency_decomposition",
    "e2e_percentiles",
    "schema_of",
    "validate_events",
    "load_manifest",
    "drift_report",
    "render_drift",
    "audit_report",
    "summarize",
]


@dataclasses.dataclass
class ObsConfig:
    """What the engine should observe and where to put it.

    ``out=None`` keeps everything in memory (``ScenarioResult.obs``);
    a path writes ``events.jsonl`` / ``metrics.prom`` / ``summary.json``
    into that directory at finalize.  ``level="lifecycle"`` drops the
    IRM decision audit (``irm.pack`` events + allocator capture).
    """

    out: Optional[str] = None
    level: str = "full"
