"""Observability analyzer CLI.

Usage (see ``--help`` per subcommand)::

    PYTHONPATH=src python -m repro.obs latency RUN/events.jsonl
    PYTHONPATH=src python -m repro.obs trace RUN/events.jsonl --msg 17
    PYTHONPATH=src python -m repro.obs audit RUN/events.jsonl
    PYTHONPATH=src python -m repro.obs diff SIM/events.jsonl LIVE/events.jsonl
    PYTHONPATH=src python -m repro.obs schema-check RUN/events.jsonl
    PYTHONPATH=src python -m repro.obs summary RUN/events.jsonl
    PYTHONPATH=src python -m repro.obs conformance RUN/events.jsonl

Exit codes: 0 clean, 1 schema violations (``schema-check``) / protocol
violations (``conformance``) or missing data, 2 usage errors.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from .analyze import (
    audit_report,
    critical_path,
    drift_report,
    e2e_percentiles,
    latency_decomposition,
    render_drift,
    summarize,
    validate_events,
)
from .exporters import load_events


def _add_log_arg(p: argparse.ArgumentParser) -> None:
    p.add_argument("events", help="path to an events.jsonl log")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="analyze observability event logs",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("latency",
                       help="decompose e2e latency per image class")
    _add_log_arg(p)
    p.add_argument("--json", action="store_true",
                   help="machine-readable output")

    p = sub.add_parser("trace", help="one message's critical path")
    _add_log_arg(p)
    p.add_argument("--msg", type=int, required=True, help="message id")

    p = sub.add_parser("audit", help="render the IRM decision audit")
    _add_log_arg(p)
    p.add_argument("--run", type=int, default=None,
                   help="only this packing run (0-based)")

    p = sub.add_parser("diff",
                       help="drift report between two event logs")
    p.add_argument("events_a")
    p.add_argument("events_b")
    p.add_argument("--json", action="store_true")

    p = sub.add_parser("schema-check",
                       help="validate a log against event_manifest.json "
                            "(exit 1 on violations)")
    _add_log_arg(p)

    p = sub.add_parser("summary", help="event counts and e2e percentiles")
    _add_log_arg(p)

    p = sub.add_parser(
        "conformance",
        help="replay the log against the protocol state machines "
             "(exit 1 on happens-before violations)",
    )
    _add_log_arg(p)
    p.add_argument("--lenient-end", action="store_true",
                   help="don't flag messages still in flight when the "
                        "log ends (for logs truncated mid-run)")

    args = ap.parse_args(argv)

    if args.cmd == "conformance":
        # shares the replay core with rule R8 of repro.analysis
        from pathlib import Path

        from ..analysis.protocol import load_committed_manifest, replay_events
        from ..analysis.protocol.conformance import load_events_file

        raw, errors = load_events_file(Path(args.events))
        for err in errors:
            print(f"warning: {err}", file=sys.stderr)
        summary = replay_events(raw, load_committed_manifest(),
                                strict_end=not args.lenient_end)
        for v in summary.violations:
            print(f"protocol violation: {v}", file=sys.stderr)
        print(f"{summary.events} events replayed: "
              f"{summary.completed} completed, "
              f"{summary.requeued} requeued, "
              f"{summary.backlog} left queued, "
              f"{len(summary.violations)} violation(s)")
        return 1 if summary.violations else 0

    if args.cmd == "diff":
        rep = drift_report(load_events(args.events_a),
                           load_events(args.events_b))
        if args.json:
            print(json.dumps(rep, indent=2))
        else:
            print(render_drift(rep))
        return 0

    events = load_events(args.events)

    if args.cmd == "latency":
        dec = latency_decomposition(events)
        pct = e2e_percentiles(events)
        if args.json:
            print(json.dumps({"by_image": dec["by_image"],
                              "totals": dec["totals"], "e2e": pct},
                             indent=2))
            return 0
        t = dec["totals"]
        print(f"{t['count']} completed messages")
        print(f"mean components: queue_wait={t['queue_wait']:.3f}s "
              f"handoff={t['handoff']:.3f}s service={t['service']:.3f}s "
              f"e2e={t['e2e']:.3f}s")
        print("per image class (mean seconds):")
        for image, agg in sorted(dec["by_image"].items()):
            print(f"  {image:<28} n={agg['count']:<5} "
                  f"queue_wait={agg['queue_wait']:.3f} "
                  f"handoff={agg['handoff']:.3f} "
                  f"service={agg['service']:.3f} e2e={agg['e2e']:.3f}")
        if pct["count"]:
            print(f"e2e latency from arrival: p50={pct['p50']:.2f}s "
                  f"p95={pct['p95']:.2f}s p99={pct['p99']:.2f}s")
        return 0

    if args.cmd == "trace":
        path = critical_path(events, args.msg)
        if not path:
            print(f"no events for msg_id {args.msg}", file=sys.stderr)
            return 1
        for hop in path:
            where = ""
            if hop["worker"] is not None:
                where = f"  worker={hop['worker']}"
                if hop["pe"] is not None:
                    where += f" pe={hop['pe']}"
            print(f"t={hop['t']:>9.3f}  (+{hop['dt']:.3f}s)  "
                  f"{hop['ev']}{where}")
        return 0

    if args.cmd == "audit":
        print(audit_report(events, run=args.run))
        return 0

    if args.cmd == "schema-check":
        violations = validate_events(events)
        if violations:
            for v in violations:
                print(f"schema violation: {v}", file=sys.stderr)
            return 1
        print(f"ok: {len(events)} events conform to the manifest")
        return 0

    if args.cmd == "summary":
        s = summarize(events)
        print(json.dumps(s, indent=2))
        return 0

    return 2  # pragma: no cover


if __name__ == "__main__":
    try:
        code = main()
    except BrokenPipeError:
        # downstream pipe (e.g. ``| head``) closed early: not an error
        sys.stderr.close()
        code = 0
    raise SystemExit(code)
