"""The typed event bus: one in-memory sink for every backend's events.

An :class:`EventBus` is handed to a backend driver (``simulate``,
``run_live``) and threaded — always behind an ``if bus is not None`` guard
so the un-observed hot paths stay untouched — to every site where a
message, worker, PE, or packing decision changes state.  ``emit`` is a
dict append: no I/O, no locks, no blocking primitives, so it is safe to
call from ``@loop_only`` code and from ``async`` bodies (R1/R2 clean by
construction).

Every event carries the same envelope:

- ``ev``   — the event type (``msg.completed``, ``irm.pack``, ...)
- ``seq``  — bus-local monotone sequence number (total order of emission)
- ``t``    — the emitting backend's current time: the scaled wall clock
  on the live runtime, the tick time in the sim
- ``tick`` — the last *nominal* control tick ``n*dt``, set by the driver
  each loop iteration; this is the time base IRM gating uses, so events
  can be joined against packing runs exactly

plus per-type payload fields.  The payload schema of every event type is
pinned in ``event_manifest.json`` and enforced two ways: rule R6 of
``repro-analyze`` checks each ``bus.emit`` call site against the manifest
at AST level, and the schema-equality test asserts all three backends
emit identical field sets at runtime.

Levels: ``"full"`` records everything including the IRM decision audit;
``"lifecycle"`` drops the (comparatively bulky) ``irm.pack`` events and
the allocator's audit capture, keeping only message/worker/PE lifecycle.
"""

from __future__ import annotations

from typing import Callable, List, Optional

from .metrics import MetricsRegistry

#: Envelope fields stamped by the bus itself on every event; everything
#: else in an event dict is that type's payload.
ENVELOPE_FIELDS = ("ev", "seq", "t", "tick")

LEVELS = ("lifecycle", "full")


class EventBus:
    """Ordered in-memory event sink plus the master's metrics registry."""

    def __init__(self, level: str = "full",
                 now: Optional[Callable[[], float]] = None) -> None:
        if level not in LEVELS:
            raise ValueError(f"obs level must be one of {LEVELS}, "
                             f"got {level!r}")
        self.level = level
        self.events: List[dict] = []
        self.registry = MetricsRegistry()
        #: last nominal control tick; drivers update this each loop pass
        self.tick = 0.0
        self.seq = 0
        #: time source for the ``t`` stamp; ``None`` falls back to the
        #: nominal tick (the sim's time base).  The live driver points
        #: this at ``ScaledClock.now`` and the engine clears it at
        #: finalize so results stay picklable.
        self.now = now

    @property
    def audit(self) -> bool:
        """Whether IRM decision-audit capture is on at this level."""
        return self.level == "full"

    def emit(self, ev: str, **fields) -> None:
        t = self.now() if self.now is not None else self.tick
        e = {"ev": ev, "seq": self.seq, "t": t, "tick": self.tick}
        e.update(fields)
        self.events.append(e)
        self.seq += 1
