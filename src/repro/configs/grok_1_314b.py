"""grok-1-314b — 8 experts top-2 MoE.  [hf:xai-org/grok-1; unverified]"""

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    norm_type="rmsnorm",
    act="gelu",
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=32768),
    rope_theta=10000.0,
    source="hf:xai-org/grok-1; unverified",
)
