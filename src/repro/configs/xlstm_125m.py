"""xlstm-125m — sLSTM + mLSTM blocks.  [arXiv:2405.04517; unverified]

d_ff=0 per the assignment: projections live inside the xLSTM blocks.  We use
a 6-layer period with one sLSTM block (positions chosen to divide the 12
layers evenly); recurrent state is O(1) per token, so long_500k runs.
"""

from .base import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    norm_type="layernorm",
    act="swiglu",
    tie_embeddings=True,
    layer_pattern="llllls",
    xlstm=XLSTMConfig(),
    source="arXiv:2405.04517; unverified",
)
