"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf]  Attention every 8th layer (offset 4, as in the HF
release: attn_layer_period=8, attn_layer_offset=4); MoE on every other layer
(expert_layer_period=2, offset=1).  Sub-quadratic (runs long_500k): only 4 of
32 layers attend; Mamba state is O(1) per token.
"""

from .base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    norm_type="rmsnorm",
    act="swiglu",
    layer_pattern="MMMMAMMM",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(num_experts=16, top_k=2, expert_d_ff=14336, period=2, offset=1),
    rope_theta=10000.0,
    source="arXiv:2403.19887; hf",
)
