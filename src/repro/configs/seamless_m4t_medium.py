"""seamless-m4t-medium — encoder-decoder multimodal backbone.

[arXiv:2308.11596; hf]  Backbone only: the speech frontend is a stub;
``input_specs()`` provides precomputed frame embeddings for the encoder.
12L encoder + 12L decoder, MHA, d_ff 4096.  RoPE replaces the original
relative positions (TPU-adaptation note in DESIGN.md).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    norm_type="layernorm",
    act="gelu",
    encdec=True,
    n_encoder_layers=12,
    frontend="audio",
    frontend_tokens=0,
    rope_theta=10000.0,
    source="arXiv:2308.11596; hf",
)
