"""Architecture configuration schema.

Every assigned architecture is a declarative ``ArchConfig``; the model
registry (``models/registry.py``) builds parameter specs and step functions
from it.  ``smoke()`` derives the reduced same-family config used by the
per-arch CPU smoke tests; the full configs are only ever lowered from
``ShapeDtypeStruct`` stand-ins in the dry-run.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

__all__ = ["MoEConfig", "SSMConfig", "XLSTMConfig", "ArchConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    # apply MoE every `period` layers with offset `offset` (jamba: 2/1);
    # period 1 means every layer is MoE.
    period: int = 1
    offset: int = 0
    # capacity factor for expert token bins (the paper's technique applied
    # to expert capacity; tokens beyond capacity are dropped GShard-style).
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2

    def is_moe_layer(self, idx: int) -> bool:
        return (idx % self.period) == self.offset if self.period > 1 else True


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective SSM (used by jamba)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 => ceil(d_model / 16)

    def inner(self, d_model: int) -> int:
        return self.expand * d_model

    def rank(self, d_model: int) -> int:
        return self.dt_rank or int(math.ceil(d_model / 16))


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block stack options (mLSTM parallel + sLSTM recurrent)."""

    # up-projection factor inside the mLSTM block
    m_proj_factor: float = 2.0
    # gated-FFN projection factor inside the sLSTM block
    s_proj_factor: float = 4.0 / 3.0
    conv_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // n_heads

    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0

    # norm / activation
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm | layernorm_np
    act: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False

    # mixture of experts
    moe: Optional[MoEConfig] = None

    # heterogeneous layer pattern, one char per layer within a period:
    #   'A' attention block, 'M' Mamba block, 'l' mLSTM block, 's' sLSTM block
    # None means all-'A'.  len(layer_pattern) must divide n_layers; the layer
    # stack is lax.scan'ed over periods with the pattern unrolled inside.
    layer_pattern: Optional[str] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # encoder-decoder (seamless): n_layers applies to the decoder
    encdec: bool = False
    n_encoder_layers: int = 0

    # modality frontend stub: number of positions filled by precomputed
    # frame/patch embeddings supplied via input_specs()
    frontend: Optional[str] = None  # None | "vision" | "audio"
    frontend_tokens: int = 0

    # serving
    sliding_window: int = 0  # 0 = full attention

    # source provenance tag from the assignment table
    source: str = ""

    # ---- derived -----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def pattern(self) -> str:
        if self.layer_pattern is None:
            return "A"
        return self.layer_pattern

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def attention_free(self) -> bool:
        return "A" not in self.pattern

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: recurrent/hybrid archs, not pure attention."""
        p = self.pattern
        return any(c in p for c in "Msl")

    def __post_init__(self) -> None:
        if self.n_heads % self.n_kv_heads != 0:
            raise ValueError("n_heads must be a multiple of n_kv_heads")
        if self.n_layers % len(self.pattern) != 0:
            raise ValueError(
                f"layer_pattern length {len(self.pattern)} must divide "
                f"n_layers {self.n_layers}"
            )
        if "M" in self.pattern and self.ssm is None:
            raise ValueError("pattern contains Mamba blocks but ssm config is None")
        if any(c in self.pattern for c in "ls") and self.xlstm is None:
            raise ValueError("pattern contains xLSTM blocks but xlstm config is None")

    # ---- reduced config for CPU smoke tests ---------------------------------
    def smoke(self) -> "ArchConfig":
        """Same-family reduced config: tiny dims, same structural features."""
        pat = self.pattern
        n_layers = max(2 * len(pat) // math.gcd(2 * len(pat), len(pat)), len(pat))
        # keep exactly two periods of the pattern
        n_layers = 2 * len(pat)
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                expert_d_ff=64,
            )
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, 4 // max(1, self.q_per_kv)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_encoder_layers=2 if self.encdec else 0,
            frontend_tokens=8 if self.frontend else 0,
            moe=moe,
        )

    # ---- parameter count (for roofline MODEL_FLOPS) -------------------------
    def param_counts(self) -> Tuple[int, int]:
        """Returns (total_params, active_params) analytically."""
        d, hd = self.d_model, self.head_dim_
        q_dim = self.n_heads * hd
        kv_dim = self.n_kv_heads * hd

        def attn_params() -> int:
            n = d * (q_dim + 2 * kv_dim) + q_dim * d
            if self.qkv_bias:
                n += q_dim + 2 * kv_dim
            if self.qk_norm:
                n += 2 * hd
            return n

        def dense_ffn() -> int:
            if self.d_ff == 0:
                return 0
            mult = 3 if self.act == "swiglu" else 2
            return mult * d * self.d_ff

        def moe_ffn(cfg: MoEConfig) -> Tuple[int, int]:
            mult = 3 if self.act == "swiglu" else 2
            per_expert = mult * d * cfg.expert_d_ff
            router = d * cfg.num_experts
            total = cfg.num_experts * per_expert + router
            active = cfg.top_k * per_expert + router
            return total, active

        def mamba_params() -> int:
            assert self.ssm is not None
            di = self.ssm.inner(d)
            r = self.ssm.rank(d)
            n = d * 2 * di  # in_proj
            n += di * self.ssm.d_conv + di  # conv + bias
            n += di * (r + 2 * self.ssm.d_state)  # x -> dt, B, C
            n += r * di + di  # dt_proj
            n += di * self.ssm.d_state + di  # A_log, D
            n += di * d  # out_proj
            return n

        def mlstm_params() -> int:
            assert self.xlstm is not None
            du = int(self.xlstm.m_proj_factor * d)
            n = d * 2 * du  # up (path, gate)
            n += du * self.xlstm.conv_kernel + du
            n += 3 * du * du + 3 * du  # q,k,v (+ igate/fgate/ogate proj)
            n += du * d
            return n

        def slstm_params() -> int:
            n = 4 * d * d + 4 * d  # i,f,z,o projections
            du = int(self.xlstm.s_proj_factor * d) if self.xlstm else d
            n += 2 * d * du + du * d  # gated FFN
            return n

        total = active = 0
        for i in range(self.n_layers):
            c = self.pattern[i % len(self.pattern)]
            if c == "A":
                total += attn_params()
                active += attn_params()
            elif c == "M":
                total += mamba_params()
                active += mamba_params()
            elif c == "l":
                total += mlstm_params()
                active += mlstm_params()
            elif c == "s":
                total += slstm_params()
                active += slstm_params()
            # FFN (attention/mamba blocks carry the FFN; xLSTM blocks don't)
            if c in ("A", "M") and (self.d_ff or self.moe):
                if self.moe is not None and self.moe.is_moe_layer(i):
                    ttl, act = moe_ffn(self.moe)
                    total += ttl
                    active += act
                elif self.d_ff:
                    total += dense_ffn()
                    active += dense_ffn()

        if self.encdec:
            # encoder self-attn + FFN, decoder cross-attn already in n_layers?
            # decoder layers get an extra cross-attention block:
            total += self.n_layers * attn_params()
            active += self.n_layers * attn_params()
            for _ in range(self.n_encoder_layers):
                total += attn_params() + dense_ffn()
                active += attn_params() + dense_ffn()

        emb = self.vocab_size * d
        total += emb if self.tie_embeddings else 2 * emb
        active += emb if self.tie_embeddings else 2 * emb
        return total, active
