"""qwen3-8b — dense GQA with per-head qk RMSNorm.  [hf:Qwen/Qwen3-8B; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    norm_type="rmsnorm",
    act="swiglu",
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B; hf",
)
