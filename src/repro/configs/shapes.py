"""Assigned input shapes (LM transformer family).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``), not ``train_step``.  ``long_500k`` requires
sub-quadratic attention: it runs only for hybrid/SSM archs
(``ArchConfig.subquadratic``); the skip for pure full-attention archs is
recorded in DESIGN.md §4.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List

__all__ = ["ShapeConfig", "SHAPES", "cells_for"]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: List[ShapeConfig] = [
    ShapeConfig("train_4k", "train", 4_096, 256),
    ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    ShapeConfig("decode_32k", "decode", 32_768, 128),
    ShapeConfig("long_500k", "decode", 524_288, 1),
]

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def cells_for(cfg) -> Iterator[ShapeConfig]:
    """The dry-run cells for an architecture, honouring the skip rules."""
    for s in SHAPES:
        if s.name == "long_500k" and not cfg.subquadratic:
            continue  # pure full-attention arch: 500k dense KV inapplicable
        yield s
