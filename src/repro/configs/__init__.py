"""Architecture registry: ``get_config(name)`` / ``--arch <id>``."""

from __future__ import annotations

import importlib
from typing import Dict, List

from .base import ArchConfig, MoEConfig, SSMConfig, XLSTMConfig
from .shapes import SHAPES, SHAPES_BY_NAME, ShapeConfig, cells_for

_MODULES = {
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "grok-1-314b": "grok_1_314b",
    "deepseek-67b": "deepseek_67b",
    "olmo-1b": "olmo_1b",
    "qwen2-72b": "qwen2_72b",
    "qwen3-8b": "qwen3_8b",
    "internvl2-1b": "internvl2_1b",
    "xlstm-125m": "xlstm_125m",
    "seamless-m4t-medium": "seamless_m4t_medium",
}

ARCH_NAMES: List[str] = list(_MODULES)

__all__ = [
    "ArchConfig",
    "MoEConfig",
    "SSMConfig",
    "XLSTMConfig",
    "ShapeConfig",
    "SHAPES",
    "SHAPES_BY_NAME",
    "cells_for",
    "ARCH_NAMES",
    "get_config",
    "all_configs",
]


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise ValueError(f"unknown arch {name!r}; options: {ARCH_NAMES}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {name: get_config(name) for name in ARCH_NAMES}
