"""internvl2-1b — VLM backbone (Qwen2-0.5B-like).  [arXiv:2404.16821; hf]

Backbone only per the assignment: the InternViT frontend is a stub;
``input_specs()`` provides precomputed patch embeddings that fill the first
``frontend_tokens`` positions of the sequence.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    norm_type="rmsnorm",
    act="swiglu",
    tie_embeddings=True,
    frontend="vision",
    frontend_tokens=256,
    rope_theta=1_000_000.0,
    source="arXiv:2404.16821; hf",
)
