"""qwen3-moe-30b-a3b — 128 experts top-8, qk-norm GQA.

[hf:Qwen/Qwen3-30B-A3B; hf]  head_dim=128 decoupled from d_model/n_heads (as
in the HF config); every layer is MoE with expert d_ff (moe_intermediate
size) 768.
"""

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    qk_norm=True,
    norm_type="rmsnorm",
    act="swiglu",
    moe=MoEConfig(num_experts=128, top_k=8, expert_d_ff=768),
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
